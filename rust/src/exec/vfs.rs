//! The store's I/O seam: every filesystem touch in the persistent tier
//! goes through the [`StoreIo`] trait.
//!
//! Two implementations ship:
//!
//! * [`RealIo`] — thin `std::fs` passthrough, plus the `mmap` fast path
//!   for segment reads (the `mm` module lived in `exec::segment` before
//!   this seam existed).
//! * [`FaultIo`] — a deterministic, seeded fault injector for the chaos
//!   test wall (`tests/chaos_store.rs`). Faults are *scheduled*, not
//!   random: the n-th I/O operation under seed `s` always receives the
//!   same fate, so a failing schedule replays exactly from its seed.
//!
//! The fault taxonomy covers the failure modes the segment tier must
//! degrade through: torn writes (a prefix lands, then the call errors),
//! short reads, single-byte corruption (checksums must catch it),
//! ENOSPC, EINTR (transient — [`with_retry`] absorbs it), failed
//! renames/metadata ops, and crash-points (`FaultPlan::crash_at`) after
//! which *every* operation fails, modelling a dead disk or a process
//! that never got to run the rest of its I/O.
//!
//! [`with_retry`] is the one retry policy in the crate: bounded attempts
//! with exponential backoff, retrying only errors [`is_transient`]
//! classifies as such. Callers that exhaust it surface the error to the
//! store, which counts it and — after repeated failures — degrades the
//! persistent tier to memory-only rather than failing simulation runs.

use std::ffi::OsString;
use std::io::{self, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::tune::plan::fnv64;

/// Raw-I/O result type; the store layers crate errors on top.
pub type IoResult<T> = std::result::Result<T, io::Error>;

/// One directory entry as reported by [`StoreIo::list_dir`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DirEntryInfo {
    pub name: OsString,
    pub len: u64,
    /// Modification time, seconds since the Unix epoch (0 when unknown).
    pub mtime_secs: u64,
    pub is_dir: bool,
}

/// A read-only mapping of a segment file (the mmap fast path). The
/// mapping is pinned for the lifetime of the value; readers slice it.
pub trait SegmentMap: Send + Sync {
    fn as_slice(&self) -> &[u8];
}

/// Every filesystem operation the persistent store performs, as one
/// injectable trait. Implementations must be safe to share across the
/// worker pool.
pub trait StoreIo: Send + Sync {
    /// Read a whole file.
    fn read(&self, path: &Path) -> IoResult<Vec<u8>>;
    /// Create-or-truncate a file with the given contents.
    fn write(&self, path: &Path, bytes: &[u8]) -> IoResult<()>;
    /// Append to a file, creating it if absent.
    fn append(&self, path: &Path, bytes: &[u8]) -> IoResult<()>;
    /// Read exactly `len` bytes at `offset`; a short file is an error
    /// (`UnexpectedEof`), never a silent prefix.
    fn read_range(&self, path: &Path, offset: u64, len: usize) -> IoResult<Vec<u8>>;
    fn rename(&self, from: &Path, to: &Path) -> IoResult<()>;
    fn remove_file(&self, path: &Path) -> IoResult<()>;
    fn create_dir_all(&self, path: &Path) -> IoResult<()>;
    fn remove_dir(&self, path: &Path) -> IoResult<()>;
    fn list_dir(&self, path: &Path) -> IoResult<Vec<DirEntryInfo>>;
    fn file_len(&self, path: &Path) -> IoResult<u64>;
    /// Map a segment file for zero-copy reads. `None` means "use
    /// [`StoreIo::read_range`]" — the contract is best-effort.
    fn map_segment(&self, _path: &Path) -> Option<Arc<dyn SegmentMap>> {
        None
    }
}

/// The production implementation: plain `std::fs`.
#[derive(Debug, Default, Clone, Copy)]
pub struct RealIo;

impl StoreIo for RealIo {
    fn read(&self, path: &Path) -> IoResult<Vec<u8>> {
        std::fs::read(path)
    }

    fn write(&self, path: &Path, bytes: &[u8]) -> IoResult<()> {
        std::fs::write(path, bytes)
    }

    fn append(&self, path: &Path, bytes: &[u8]) -> IoResult<()> {
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(bytes)
    }

    fn read_range(&self, path: &Path, offset: u64, len: usize) -> IoResult<Vec<u8>> {
        let mut f = std::fs::File::open(path)?;
        let mut buf = vec![0u8; len];
        read_exact_at(&mut f, &mut buf, offset)?;
        Ok(buf)
    }

    fn rename(&self, from: &Path, to: &Path) -> IoResult<()> {
        std::fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> IoResult<()> {
        std::fs::remove_file(path)
    }

    fn create_dir_all(&self, path: &Path) -> IoResult<()> {
        std::fs::create_dir_all(path)
    }

    fn remove_dir(&self, path: &Path) -> IoResult<()> {
        std::fs::remove_dir(path)
    }

    fn list_dir(&self, path: &Path) -> IoResult<Vec<DirEntryInfo>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(path)? {
            let entry = entry?;
            let meta = entry.metadata()?;
            let mtime_secs = meta
                .modified()
                .ok()
                .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                .map(|d| d.as_secs())
                .unwrap_or(0);
            out.push(DirEntryInfo {
                name: entry.file_name(),
                len: meta.len(),
                mtime_secs,
                is_dir: meta.is_dir(),
            });
        }
        Ok(out)
    }

    fn file_len(&self, path: &Path) -> IoResult<u64> {
        Ok(std::fs::metadata(path)?.len())
    }

    fn map_segment(&self, path: &Path) -> Option<Arc<dyn SegmentMap>> {
        map_segment_real(path)
    }
}

/// The default (production) I/O implementation.
pub fn default_io() -> Arc<dyn StoreIo> {
    Arc::new(RealIo)
}

fn read_exact_at(f: &mut std::fs::File, buf: &mut [u8], offset: u64) -> IoResult<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        f.read_exact_at(buf, offset)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek};
        f.seek(io::SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

#[cfg(all(feature = "mmap", unix, target_pointer_width = "64"))]
fn map_segment_real(path: &Path) -> Option<Arc<dyn SegmentMap>> {
    let file = std::fs::File::open(path).ok()?;
    mm::map_file(&file).map(|m| Arc::new(m) as Arc<dyn SegmentMap>)
}

#[cfg(not(all(feature = "mmap", unix, target_pointer_width = "64")))]
fn map_segment_real(_path: &Path) -> Option<Arc<dyn SegmentMap>> {
    None
}

// ---------------------------------------------------------------------------
// Retry policy
// ---------------------------------------------------------------------------

/// Maximum attempts [`with_retry`] makes (1 initial + 2 retries).
pub const RETRY_ATTEMPTS: u32 = 3;

/// Whether an I/O error is worth retrying: the OS interrupted or timed
/// the call out without changing any state. Everything else (ENOSPC,
/// corruption, permission, a dead disk) retries identically, so retrying
/// would only delay the degradation path.
pub fn is_transient(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// Run `f` with bounded retry and exponential backoff on transient
/// errors. Non-transient errors return immediately.
pub fn with_retry<T>(mut f: impl FnMut() -> IoResult<T>) -> IoResult<T> {
    let mut attempt = 0;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if is_transient(&e) && attempt + 1 < RETRY_ATTEMPTS => {
                attempt += 1;
                std::thread::sleep(Duration::from_millis(1 << attempt));
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// A deterministic fault schedule. The schedule is a pure function of
/// `(seed, operation index)`, so a run under a given plan is exactly
/// reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Roughly one in `period` operations faults; `0` disables
    /// scheduled faults entirely (crash-points still apply).
    pub period: u64,
    /// Operation index after which every call fails — a crash / dead
    /// disk. `Some(0)` means the disk was never usable.
    pub crash_at: Option<u64>,
}

impl FaultPlan {
    /// Derive a varied schedule from a bare seed: fault density between
    /// 1-in-2 and 1-in-8 ops, and about a quarter of seeds also get a
    /// crash-point within the first ~96 operations.
    pub fn from_seed(seed: u64) -> Self {
        let h = fnv64(&seed.to_le_bytes());
        let period = 2 + (h % 7);
        let crash_at = if h % 4 == 0 { Some(1 + ((h >> 8) % 96)) } else { None };
        Self { seed, period, crash_at }
    }

    /// No scheduled faults, crash after exactly `n` operations.
    pub fn crash_after(n: u64) -> Self {
        Self { seed: 0, period: 0, crash_at: Some(n) }
    }

    /// Every operation fails from the start: a dead disk.
    pub fn dead_disk() -> Self {
        Self::crash_after(0)
    }
}

enum OpClass {
    Read,
    Write,
    Meta,
}

enum Fault {
    /// Past the crash-point: everything fails.
    Crash,
    /// Transient EINTR; no side effect. [`with_retry`] absorbs it.
    Eintr,
    /// Hard failure with no side effect.
    Fail(&'static str),
    /// No space left on device; no side effect.
    Enospc,
    /// A prefix of the payload lands, then the call errors.
    Torn(u64),
    /// A read returns fewer bytes than the file holds.
    Short(u64),
    /// A read succeeds but one byte is flipped. Frame checksums must
    /// catch this — the one fault that returns `Ok` with bad data.
    Corrupt(u64),
}

impl Fault {
    fn into_err(self) -> io::Error {
        match self {
            Fault::Crash => io::Error::new(io::ErrorKind::Other, "injected crash: disk is gone"),
            Fault::Eintr => io::Error::new(io::ErrorKind::Interrupted, "injected EINTR"),
            Fault::Fail(what) => io::Error::new(io::ErrorKind::Other, what),
            Fault::Enospc => {
                io::Error::new(io::ErrorKind::Other, "injected ENOSPC: no space left on device")
            }
            Fault::Torn(_) => io::Error::new(io::ErrorKind::Other, "injected torn write"),
            Fault::Short(_) | Fault::Corrupt(_) => {
                io::Error::new(io::ErrorKind::Other, "injected read failure")
            }
        }
    }
}

/// [`StoreIo`] decorator that injects faults per a [`FaultPlan`].
///
/// `map_segment` always returns `None` so every segment read goes
/// through the injectable `read_range` path.
pub struct FaultIo {
    inner: Arc<dyn StoreIo>,
    plan: FaultPlan,
    ops: AtomicU64,
    injected: AtomicU64,
}

impl FaultIo {
    pub fn new(inner: Arc<dyn StoreIo>, plan: FaultPlan) -> Self {
        Self { inner, plan, ops: AtomicU64::new(0), injected: AtomicU64::new(0) }
    }

    /// Faults over the real filesystem, schedule derived from `seed`.
    pub fn seeded(seed: u64) -> Self {
        Self::new(Arc::new(RealIo), FaultPlan::from_seed(seed))
    }

    /// Total operations observed so far.
    pub fn op_count(&self) -> u64 {
        self.ops.load(Ordering::SeqCst)
    }

    /// Faults injected so far (crash-mode failures count once each).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// Whether the crash-point has been reached.
    pub fn crashed(&self) -> bool {
        self.plan.crash_at.is_some_and(|c| self.op_count() >= c)
    }

    fn decide(&self, class: OpClass) -> Option<Fault> {
        let n = self.ops.fetch_add(1, Ordering::SeqCst);
        if self.plan.crash_at.is_some_and(|c| n >= c) {
            self.injected.fetch_add(1, Ordering::SeqCst);
            return Some(Fault::Crash);
        }
        if self.plan.period == 0 {
            return None;
        }
        let mut bytes = [0u8; 16];
        bytes[..8].copy_from_slice(&self.plan.seed.to_le_bytes());
        bytes[8..].copy_from_slice(&n.to_le_bytes());
        let h = fnv64(&bytes);
        if h % self.plan.period != 0 {
            return None;
        }
        self.injected.fetch_add(1, Ordering::SeqCst);
        let r = h / self.plan.period;
        Some(match class {
            OpClass::Read => match r % 4 {
                0 => Fault::Short(r >> 2),
                1 => Fault::Corrupt(r >> 2),
                2 => Fault::Eintr,
                _ => Fault::Fail("injected read failure"),
            },
            OpClass::Write => match r % 3 {
                0 => Fault::Torn(r / 3),
                1 => Fault::Enospc,
                _ => Fault::Eintr,
            },
            OpClass::Meta => match r % 3 {
                0 => Fault::Fail("injected metadata failure"),
                1 => Fault::Enospc,
                _ => Fault::Eintr,
            },
        })
    }
}

impl StoreIo for FaultIo {
    fn read(&self, path: &Path) -> IoResult<Vec<u8>> {
        match self.decide(OpClass::Read) {
            None => self.inner.read(path),
            Some(Fault::Short(r)) => {
                let mut b = self.inner.read(path)?;
                let keep = (r as usize) % (b.len() + 1);
                b.truncate(keep);
                Ok(b)
            }
            Some(Fault::Corrupt(r)) => {
                let mut b = self.inner.read(path)?;
                if !b.is_empty() {
                    let i = (r as usize) % b.len();
                    b[i] ^= 0x20;
                }
                Ok(b)
            }
            Some(f) => Err(f.into_err()),
        }
    }

    fn write(&self, path: &Path, bytes: &[u8]) -> IoResult<()> {
        match self.decide(OpClass::Write) {
            None => self.inner.write(path, bytes),
            Some(Fault::Torn(r)) => {
                let keep = (r as usize) % (bytes.len() + 1);
                let _ = self.inner.write(path, &bytes[..keep]);
                Err(Fault::Torn(r).into_err())
            }
            Some(f) => Err(f.into_err()),
        }
    }

    fn append(&self, path: &Path, bytes: &[u8]) -> IoResult<()> {
        match self.decide(OpClass::Write) {
            None => self.inner.append(path, bytes),
            Some(Fault::Torn(r)) => {
                let keep = (r as usize) % (bytes.len() + 1);
                let _ = self.inner.append(path, &bytes[..keep]);
                Err(Fault::Torn(r).into_err())
            }
            Some(f) => Err(f.into_err()),
        }
    }

    fn read_range(&self, path: &Path, offset: u64, len: usize) -> IoResult<Vec<u8>> {
        match self.decide(OpClass::Read) {
            None => self.inner.read_range(path, offset, len),
            Some(Fault::Short(_)) => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "injected short positioned read",
            )),
            Some(Fault::Corrupt(r)) => {
                let mut b = self.inner.read_range(path, offset, len)?;
                if !b.is_empty() {
                    let i = (r as usize) % b.len();
                    b[i] ^= 0x20;
                }
                Ok(b)
            }
            Some(f) => Err(f.into_err()),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> IoResult<()> {
        match self.decide(OpClass::Meta) {
            None => self.inner.rename(from, to),
            Some(f) => Err(f.into_err()),
        }
    }

    fn remove_file(&self, path: &Path) -> IoResult<()> {
        match self.decide(OpClass::Meta) {
            None => self.inner.remove_file(path),
            Some(f) => Err(f.into_err()),
        }
    }

    fn create_dir_all(&self, path: &Path) -> IoResult<()> {
        match self.decide(OpClass::Meta) {
            None => self.inner.create_dir_all(path),
            Some(f) => Err(f.into_err()),
        }
    }

    fn remove_dir(&self, path: &Path) -> IoResult<()> {
        match self.decide(OpClass::Meta) {
            None => self.inner.remove_dir(path),
            Some(f) => Err(f.into_err()),
        }
    }

    fn list_dir(&self, path: &Path) -> IoResult<Vec<DirEntryInfo>> {
        match self.decide(OpClass::Read) {
            None => self.inner.list_dir(path),
            Some(Fault::Short(r)) => {
                let mut entries = self.inner.list_dir(path)?;
                let keep = (r as usize) % (entries.len() + 1);
                entries.truncate(keep);
                Ok(entries)
            }
            Some(Fault::Corrupt(_)) => {
                Err(io::Error::new(io::ErrorKind::Other, "injected listing failure"))
            }
            Some(f) => Err(f.into_err()),
        }
    }

    fn file_len(&self, path: &Path) -> IoResult<u64> {
        match self.decide(OpClass::Meta) {
            None => self.inner.file_len(path),
            Some(f) => Err(f.into_err()),
        }
    }
}

// ---------------------------------------------------------------------------
// mmap (moved here from exec::segment when the I/O seam was introduced)
// ---------------------------------------------------------------------------

/// Minimal read-only mmap over a file, used for segment reads when the
/// `mmap` feature is on. No external crates: raw libc via `extern "C"`.
#[cfg(all(feature = "mmap", unix, target_pointer_width = "64"))]
mod mm {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    const PROT_READ: i32 = 0x1;
    const MAP_SHARED: i32 = 0x1;

    pub struct Mmap {
        ptr: *mut u8,
        len: usize,
    }

    // The mapping is read-only and lives until Drop; sharing the raw
    // pointer across threads is safe.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        pub fn len(&self) -> usize {
            self.len
        }

        pub fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            if !self.ptr.is_null() && self.len > 0 {
                unsafe {
                    munmap(self.ptr, self.len);
                }
            }
        }
    }

    impl super::SegmentMap for Mmap {
        fn as_slice(&self) -> &[u8] {
            Mmap::as_slice(self)
        }
    }

    pub fn map_file(file: &File) -> Option<Mmap> {
        let len = file.metadata().ok()?.len();
        if len == 0 || len > usize::MAX as u64 {
            return None;
        }
        let len = len as usize;
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_SHARED, file.as_raw_fd(), 0)
        };
        if ptr.is_null() || ptr as isize == -1 {
            return None;
        }
        Some(Mmap { ptr, len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("multistride_vfs_{tag}_{}", std::process::id()))
    }

    #[test]
    fn retry_absorbs_transient_errors() {
        let mut calls = 0;
        let out: IoResult<u32> = with_retry(|| {
            calls += 1;
            if calls < 3 {
                Err(io::Error::new(io::ErrorKind::Interrupted, "eintr"))
            } else {
                Ok(7)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls, 3);
    }

    #[test]
    fn retry_gives_up_on_hard_errors_immediately() {
        let mut calls = 0;
        let out: IoResult<u32> = with_retry(|| {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::Other, "enospc"))
        });
        assert!(out.is_err());
        assert_eq!(calls, 1, "hard errors must not be retried");
    }

    #[test]
    fn retry_is_bounded_for_persistent_transients() {
        let mut calls = 0;
        let out: IoResult<u32> = with_retry(|| {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::Interrupted, "eintr forever"))
        });
        assert!(out.is_err());
        assert_eq!(calls, RETRY_ATTEMPTS as usize, "bounded attempts");
    }

    /// Same seed, same op sequence: identical outcomes, op for op.
    #[test]
    fn fault_schedule_is_deterministic() {
        let dir = tmp("det");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("x");
        std::fs::write(&file, b"0123456789abcdef").unwrap();
        for seed in 0..16u64 {
            let run = |_: u64| {
                let io = FaultIo::seeded(seed);
                let mut outcomes = Vec::new();
                for _ in 0..32 {
                    outcomes.push(match io.read(&file) {
                        Ok(b) => format!("ok:{}", b.len()),
                        Err(e) => format!("err:{}", e.kind()),
                    });
                }
                (outcomes, io.injected())
            };
            assert_eq!(run(seed), run(seed), "seed {seed} must replay identically");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Across a modest seed range, every fault kind actually fires.
    #[test]
    fn fault_taxonomy_is_exercised() {
        let dir = tmp("taxonomy");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("payload");
        let full = b"the quick brown fox jumps over the lazy dog".to_vec();
        std::fs::write(&file, &full).unwrap();
        let (mut short, mut corrupt, mut eintr, mut torn, mut enospc) = (0, 0, 0, 0, 0);
        for seed in 0..64u64 {
            let io = FaultIo::seeded(seed);
            for _ in 0..16 {
                match io.read(&file) {
                    Ok(b) if b.len() < full.len() => short += 1,
                    Ok(b) if b != full => corrupt += 1,
                    Ok(_) => {}
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => eintr += 1,
                    Err(_) => {}
                }
            }
            let out = dir.join(format!("out{seed}"));
            for _ in 0..16 {
                std::fs::remove_file(&out).ok();
                match io.write(&out, &full) {
                    Ok(()) => {}
                    Err(e) => {
                        let on_disk = std::fs::read(&out).map(|b| b.len()).unwrap_or(0);
                        if on_disk > 0 && on_disk < full.len() {
                            torn += 1;
                        }
                        if e.to_string().contains("ENOSPC") {
                            enospc += 1;
                        }
                    }
                }
            }
        }
        assert!(short > 0, "short reads must occur");
        assert!(corrupt > 0, "corrupt reads must occur");
        assert!(eintr > 0, "EINTR must occur");
        assert!(torn > 0, "torn writes must occur");
        assert!(enospc > 0, "ENOSPC must occur");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_point_kills_all_later_ops() {
        let dir = tmp("crash");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("f");
        std::fs::write(&file, b"data").unwrap();
        let io = FaultIo::new(Arc::new(RealIo), FaultPlan::crash_after(3));
        for i in 0..3 {
            assert!(io.read(&file).is_ok(), "op {i} is before the crash-point");
        }
        assert!(!io.crashed());
        for i in 3..8 {
            assert!(io.read(&file).is_err(), "op {i} is past the crash-point");
        }
        assert!(io.crashed());
        let dead = FaultIo::new(Arc::new(RealIo), FaultPlan::dead_disk());
        assert!(dead.read(&file).is_err(), "a dead disk never serves");
        std::fs::remove_dir_all(&dir).ok();
    }
}
