//! Batch planning: dedup a request's job set against itself and the
//! store, then schedule only the survivors over the warm-engine pool.
//!
//! [`Planner::run`] is the execution path behind every multi-point
//! experiment driver (`figure2`, `figure3_4`, `figure6`,
//! `variant_sweep`, …): the driver expands its request into an ordered
//! `Vec<SimPoint>` (the *plan-builder* half), the planner resolves each
//! point to an `Arc<RunResult>` in input order (this module), and the
//! driver formats the results (the *result-formatter* half). Identical
//! points — inside one batch, across batches in one process, or across
//! processes via the persistent tier — simulate **once**.
//!
//! Scheduling reuses the existing coordinator machinery unchanged:
//! [`parallel_map_with`] with one [`EngineCache`] per worker, so every
//! missing point runs on a warm engine exactly as the pre-store sweeps
//! did (bit-identically — that is the engine-reuse contract
//! `tests/golden_determinism.rs` pins).
//!
//! [`simulate`] is the single place a [`SimPoint`] becomes an engine
//! run; the planner, the store's single-point
//! [`ResultStore::get_or_run`] path, and `lifecycle::verify`'s
//! re-simulate-and-compare sweep all go through it. Misses write
//! through to the store's segment tier (`exec::segment`), so a batch's
//! results persist as packed records, not a file per point.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::experiments::EngineCache;
use crate::coordinator::pool::{default_workers, parallel_map_with};
use crate::kernels::library::kernel_by_name;
use crate::kernels::micro::MicroBench;
use crate::sim::{EngineConfig, RunResult};
use crate::trace::KernelTrace;
use crate::transform::transform;
use crate::{format_err, Result};

use super::point::{SimPoint, Workload};
use super::store::ResultStore;

/// Run one point on a (warm) engine. Deterministic: equal keys produce
/// bit-identical results, in fresh or reused engines alike.
pub fn simulate(engines: &mut EngineCache, point: &SimPoint) -> Result<RunResult> {
    let cfg = EngineConfig::new(point.machine)
        .with_prefetch(point.prefetch)
        .with_huge_pages(point.huge_pages);
    match &point.workload {
        Workload::Micro { op, strides, bytes, interleaved } => {
            let mut bench = MicroBench::new(*op, *strides, *bytes);
            if *interleaved {
                bench = bench.interleaved();
            }
            Ok(engines.engine_for(cfg).run(bench.trace()))
        }
        Workload::Kernel { name, budget, config } => {
            let pk = kernel_by_name(name, *budget)
                .ok_or_else(|| format_err!("unknown kernel {name}"))?;
            let t = transform(&pk.spec, *config)
                .map_err(|e| format_err!("kernel {name}: untransformable point: {e}"))?;
            let trace = KernelTrace::new(t);
            Ok(engines.engine_for(cfg).run(trace.iter()))
        }
    }
}

/// Batch executor over one [`ResultStore`].
pub struct Planner<'a> {
    store: &'a ResultStore,
    workers: usize,
}

impl<'a> Planner<'a> {
    pub fn new(store: &'a ResultStore) -> Self {
        Self { store, workers: default_workers() }
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Resolve every point to its result, in input order. Duplicate keys
    /// collapse to one simulation; points already in the store are
    /// served without any engine work. Errors only if a point fails to
    /// simulate (drivers validate transformability before enqueueing, so
    /// an error here is a bug, not a data condition) — or, debug builds
    /// only, panics if a served hit diverges from a fresh simulation.
    pub fn run(&self, points: &[SimPoint]) -> Result<Vec<Arc<RunResult>>> {
        let _span = crate::obs::span("plan_batch");
        // Phase 1 — resolve against the store, dedup within the batch.
        // `None` marks a key scheduled for simulation below.
        let mut resolved: HashMap<u64, Option<Arc<RunResult>>> = HashMap::new();
        let mut to_run: Vec<&SimPoint> = Vec::new();
        #[cfg(debug_assertions)]
        let mut to_verify: Vec<&SimPoint> = Vec::new();
        for p in points {
            if resolved.contains_key(&p.key()) {
                self.store.note_dedup();
                continue;
            }
            match self.store.lookup(p.key()) {
                Some(r) => {
                    #[cfg(debug_assertions)]
                    to_verify.push(p);
                    resolved.insert(p.key(), Some(r));
                }
                None => {
                    self.store.note_miss();
                    resolved.insert(p.key(), None);
                    to_run.push(p);
                }
            }
        }

        // Phase 2 — simulate the survivors on the worker pool, one warm
        // engine per worker, and write each result through the store.
        let fresh = parallel_map_with(to_run, self.workers, EngineCache::new, |engines, p| {
            self.store.note_engine_run();
            let _span = crate::obs::span("engine_run");
            simulate(engines, p).map(|r| {
                crate::obs::fold_run_result(&r);
                (p.key(), Arc::new(r))
            })
        });
        // (`p` above is `&&SimPoint`: the pool hands `&J` with `J = &SimPoint`;
        // auto-deref covers the calls.)
        for item in fresh {
            let (key, r) = item?;
            self.store.insert(key, Arc::clone(&r));
            resolved.insert(key, Some(r));
        }

        // Debug safety net: every distinct hit re-simulates on the same
        // pool and must match the served bytes (see the store docs). An
        // unsimulatable hit self-heals to a miss and surfaces as an error.
        #[cfg(debug_assertions)]
        {
            let checks =
                parallel_map_with(to_verify, self.workers, EngineCache::new, |engines, p| {
                    let hit = resolved[&p.key()].as_ref().expect("hit resolved in phase 1");
                    self.store.verify_hit(engines, p, hit)
                });
            for c in checks {
                c?;
            }
        }

        // Phase 3 — serve the batch in input order.
        Ok(points
            .iter()
            .map(|p| {
                Arc::clone(
                    resolved[&p.key()].as_ref().expect("every scheduled key simulated"),
                )
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::exec::format::serialize_result;
    use crate::kernels::micro::MicroOp;
    use crate::transform::StridingConfig;

    const MIB: u64 = 1 << 20;

    fn batch() -> Vec<SimPoint> {
        let m = coffee_lake();
        vec![
            SimPoint::micro(m, MicroOp::LoadAligned, 1, MIB, true, false),
            SimPoint::micro(m, MicroOp::LoadAligned, 4, MIB, true, false),
            // Deliberate duplicate of the first point.
            SimPoint::micro(m, MicroOp::LoadAligned, 1, MIB, true, false),
            SimPoint::kernel(m, "init", MIB, StridingConfig::new(2, 1), true).unwrap(),
        ]
    }

    #[test]
    fn batch_dedups_and_preserves_input_order() {
        let store = ResultStore::ephemeral();
        let points = batch();
        let out = Planner::new(&store).with_workers(2).run(&points).unwrap();
        assert_eq!(out.len(), points.len());
        assert!(
            Arc::ptr_eq(&out[0], &out[2]),
            "duplicate points share one simulation"
        );
        let s = store.stats();
        assert_eq!(s.engine_runs, 3, "3 distinct keys in a 4-point batch");
        assert_eq!(s.deduped, 1);
        assert_eq!(s.requests, 4);

        // Re-running the identical batch is all memory hits, zero sims.
        let again = Planner::new(&store).with_workers(2).run(&points).unwrap();
        let s = store.stats();
        assert_eq!(s.engine_runs, 3, "warm batch performs no engine runs");
        assert_eq!(s.mem_hits, 3);
        assert_eq!(s.deduped, 2);
        for (a, b) in out.iter().zip(&again) {
            assert!(Arc::ptr_eq(a, b));
        }
    }

    #[test]
    fn parallel_batch_matches_serial_cold_run_bit_for_bit() {
        let points = batch();
        let serial_store = ResultStore::ephemeral();
        let serial = Planner::new(&serial_store).with_workers(1).run(&points).unwrap();
        let par_store = ResultStore::ephemeral();
        let parallel = Planner::new(&par_store).with_workers(4).run(&points).unwrap();
        for ((p, a), b) in points.iter().zip(&serial).zip(&parallel) {
            assert_eq!(
                serialize_result(p.key(), a),
                serialize_result(p.key(), b),
                "{}",
                p.label()
            );
        }
    }

    #[test]
    fn single_point_path_agrees_with_the_batch_path() {
        let store = ResultStore::ephemeral();
        let points = batch();
        let out = Planner::new(&store).run(&points).unwrap();
        let solo = store
            .get_or_run(&mut EngineCache::new(), &points[3])
            .unwrap();
        assert!(Arc::ptr_eq(&out[3], &solo), "get_or_run hits the batch's entry");
    }
}
