//! Append-only segment files: the packed persistent tier of the result
//! store.
//!
//! PR 5's disk tier paid a file-open-read-parse round trip per
//! [`super::SimPoint`] — fine at figure scale, hopeless at the
//! million-point scale the ROADMAP's serving daemon needs. This module
//! packs records into sequentially-laid-out segments instead, the same
//! burst-friendly-layout move the paper makes for DRAM:
//!
//! ```text
//! <results>/seg-0000.bin   8-byte magic, then back-to-back records
//! <results>/seg-0001.bin   … (a new segment starts when one reaches
//! <results>/index.msidx        the roll size)
//! ```
//!
//! **Record frame** (all integers little-endian):
//!
//! ```text
//! key: u64 | stamp: u64 (unix seconds) | len: u32 | payload | fnv64: u64
//! ```
//!
//! The checksum covers header + payload, so torn writes, bit flips and
//! key/payload mismatches are all one failure mode: the record does not
//! validate and the point degrades to a self-healing miss. The payload
//! is [`super::format::encode_result_bin`]'s fixed-width encoding —
//! serving a hit is checksum + 52 word copies, no text walk.
//!
//! **Index** (`index.msidx`): a flat binary map `point_key → (segment,
//! offset, len, stamp)` plus per-segment scan coverage, FNV-checksummed
//! and written atomically (tmp + rename) when the in-memory state is
//! dirty. The index is a pure cache of what a segment scan would find:
//! [`SegmentStore::open`] loads it once, distrusts anything implausible
//! (bad checksum, entries past a segment's scanned coverage, segments
//! that shrank) and rebuilds the missing knowledge by scanning exactly
//! the uncovered byte ranges. A scan stops at the first invalid record
//! and **seals** the segment — the writer never appends past damage; it
//! rolls to a fresh segment instead, which is what makes a torn tail
//! self-healing rather than contagious.
//!
//! **Reads** are zero-copy where the platform allows: segments are
//! memory-mapped (default-on `mmap` cargo feature; raw `libc` bindings,
//! the crate takes no dependencies) and a hit validates its checksum in
//! place. With `--no-default-features`, or past the mapped length of a
//! segment that grew after mapping, the same bytes come from a
//! positioned file read — both paths serve identical bytes.
//!
//! **I/O** goes through [`super::vfs::StoreIo`] exclusively — the real
//! filesystem in production, a seeded fault injector under the chaos
//! wall. Transient errors are absorbed by [`super::vfs::with_retry`]; a
//! failed append seals the segment (the bytes on disk are suspect) and
//! the writer rolls to a fresh one, so torn writes stay self-healing
//! even while the process keeps running.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::sim::RunResult;
use crate::tune::plan::fnv64;
use crate::{ensure, Result};

use super::format::{decode_result_bin, encode_result_bin};
use super::vfs::{default_io, with_retry, SegmentMap, StoreIo};

/// First bytes of every segment file; doubles as the format version.
pub const SEGMENT_MAGIC: [u8; 8] = *b"MSSEG01\n";

/// First bytes of the index file.
pub const INDEX_MAGIC: [u8; 8] = *b"MSIDX01\n";

/// Index file name inside the results directory.
pub const INDEX_FILE: &str = "index.msidx";

/// Default segment roll size. At today's ~444-byte records a million
/// points pack into a handful of segments, each mapped once.
pub const DEFAULT_ROLL_BYTES: u64 = 64 << 20;

/// key + stamp + len prefix.
const RECORD_HEADER_BYTES: usize = 20;

/// Trailing FNV-1a checksum.
const RECORD_TRAILER_BYTES: usize = 8;

/// Scan sanity cap: a length prefix beyond this is treated as garbage
/// rather than chased across the file.
const MAX_PAYLOAD_BYTES: usize = 1 << 20;

/// Canonical file name of segment `id`.
pub fn segment_file_name(id: u32) -> String {
    format!("seg-{id:04}.bin")
}

fn parse_segment_name(name: &std::ffi::OsStr) -> Option<u32> {
    let digits = name.to_str()?.strip_prefix("seg-")?.strip_suffix(".bin")?;
    if digits.len() < 4 || digits.len() > 9 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Seconds since the UNIX epoch — the record stamp gc ages against.
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Where a live record lives, as the index maps it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Loc {
    /// Segment id (`seg-NNNN.bin`).
    pub seg: u32,
    /// Byte offset of the record frame inside the segment.
    pub offset: u64,
    /// Total frame length (header + payload + checksum).
    pub len: u32,
    /// Unix seconds at append time; gc's age signal.
    pub stamp: u64,
}

#[derive(Debug, Clone, Copy)]
struct SegMeta {
    /// Current file length in bytes.
    len: u64,
    /// Bytes known to hold valid records (from the index or a scan).
    covered: u64,
    /// A scan hit invalid bytes at `covered`; never append here again.
    sealed: bool,
}

struct SegmentWriter {
    id: u32,
    len: u64,
}

/// What [`SegmentStore::compact`] did.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompactStats {
    /// Live records rewritten into fresh segments.
    pub rewritten: u64,
    /// Records that failed validation during the rewrite and were dropped.
    pub dropped: u64,
    /// On-disk bytes reclaimed by deleting the old segments.
    pub reclaimed_bytes: u64,
}

/// One directory of segment files plus its index, owned exclusively by
/// the opener. All mutation is in-memory except record appends (written
/// immediately, unbuffered) and [`SegmentStore::flush_index`].
pub struct SegmentStore {
    dir: PathBuf,
    roll_bytes: u64,
    io: Arc<dyn StoreIo>,
    map: HashMap<u64, Loc>,
    segments: BTreeMap<u32, SegMeta>,
    /// Per-segment read mapping (`None`: mapping unavailable, reads go
    /// through [`StoreIo::read_range`]).
    readers: HashMap<u32, Option<Arc<dyn SegmentMap>>>,
    writer: Option<SegmentWriter>,
    /// Floor for new writer segments; compaction raises it so rewritten
    /// records never land in a segment scheduled for deletion.
    min_writer_seg: u32,
    dirty: bool,
    open_corruption: u64,
    index_loaded: bool,
}

impl SegmentStore {
    /// Open (or implicitly create) the segment store under `dir` with
    /// the default (real) I/O. Never fails: a missing directory is an
    /// empty store, and any damage — corrupt index, torn records,
    /// shrunken segments — is absorbed by rescanning and counted in
    /// [`SegmentStore::take_open_corruption`].
    pub fn open(dir: impl Into<PathBuf>, roll_bytes: u64) -> Self {
        Self::open_with(dir, roll_bytes, default_io())
    }

    /// [`SegmentStore::open`] over an explicit [`StoreIo`] (the fault
    /// injector in chaos tests). Unreadable directories or segments
    /// degrade to an empty/partial view, never a panic.
    pub fn open_with(dir: impl Into<PathBuf>, roll_bytes: u64, io: Arc<dyn StoreIo>) -> Self {
        let mut st = SegmentStore {
            dir: dir.into(),
            roll_bytes: roll_bytes.max(1),
            io,
            map: HashMap::new(),
            segments: BTreeMap::new(),
            readers: HashMap::new(),
            writer: None,
            min_writer_seg: 0,
            dirty: false,
            open_corruption: 0,
            index_loaded: false,
        };
        if let Ok(entries) = st.io.list_dir(&st.dir) {
            for e in entries {
                if e.is_dir {
                    continue;
                }
                if let Some(id) = parse_segment_name(&e.name) {
                    st.segments.insert(id, SegMeta { len: e.len, covered: 0, sealed: false });
                }
            }
        }
        match load_index(&*st.io, &st.dir.join(INDEX_FILE)) {
            Ok(None) => {}
            Ok(Some(idx)) => {
                st.index_loaded = true;
                let mut trusted: HashMap<u32, u64> = HashMap::new();
                for (id, covered, sealed) in idx.segs {
                    if let Some(meta) = st.segments.get_mut(&id) {
                        if covered <= meta.len {
                            meta.covered = covered;
                            meta.sealed = sealed;
                            trusted.insert(id, covered);
                        } else {
                            // The segment shrank under the index: the
                            // index's offsets are fiction, rescan it.
                            st.open_corruption += 1;
                            st.dirty = true;
                        }
                    }
                }
                for (key, loc) in idx.entries {
                    let end = loc.offset.saturating_add(loc.len as u64);
                    let ok = matches!(trusted.get(&loc.seg), Some(&cov) if end <= cov);
                    if ok {
                        st.map.insert(key, loc);
                    } else {
                        // Entry points at a missing/distrusted segment or
                        // past its coverage; a scan below re-derives the
                        // truth.
                        st.dirty = true;
                    }
                }
            }
            Err(e) => {
                eprintln!(
                    "[exec] corrupt segment index under {}: {e} — rebuilding from segment scans",
                    st.dir.display()
                );
                st.open_corruption += 1;
                st.dirty = true;
            }
        }
        let ids: Vec<u32> = st.segments.keys().copied().collect();
        for id in ids {
            let meta = *st.segments.get(&id).expect("listed above");
            if meta.sealed || meta.covered >= meta.len {
                continue;
            }
            let scan = scan_segment(&*st.io, &st.segment_path(id), id, meta.covered);
            for (key, loc) in scan.entries {
                st.map.insert(key, loc);
            }
            let m = st.segments.get_mut(&id).expect("listed above");
            m.covered = scan.covered;
            if !scan.clean {
                m.sealed = true;
                st.open_corruption += 1;
            }
            st.dirty = true;
        }
        st
    }

    /// Directory this store owns.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn segment_path(&self, id: u32) -> PathBuf {
        self.dir.join(segment_file_name(id))
    }

    /// Number of live (indexed) records.
    pub fn entry_count(&self) -> u64 {
        self.map.len() as u64
    }

    /// Total frame bytes of live records.
    pub fn live_bytes(&self) -> u64 {
        self.map.values().map(|l| l.len as u64).sum()
    }

    pub fn segment_count(&self) -> u64 {
        self.segments.len() as u64
    }

    pub fn segment_bytes(&self) -> u64 {
        self.segments.values().map(|m| m.len).sum()
    }

    pub fn sealed_count(&self) -> u64 {
        self.segments.values().filter(|m| m.sealed).count() as u64
    }

    /// Bytes not attributable to live records or file headers: dead
    /// (removed, superseded or damaged) weight compaction reclaims.
    pub fn dead_bytes(&self) -> u64 {
        let overhead = self.segment_count() * SEGMENT_MAGIC.len() as u64;
        self.segment_bytes().saturating_sub(self.live_bytes() + overhead)
    }

    /// Whether open() found a usable index (vs. rebuilding from scans).
    pub fn index_loaded(&self) -> bool {
        self.index_loaded
    }

    /// Corruption events absorbed while opening; resets the counter.
    pub fn take_open_corruption(&mut self) -> u64 {
        std::mem::take(&mut self.open_corruption)
    }

    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Snapshot of every live entry (arbitrary order).
    pub fn entries(&self) -> Vec<(u64, Loc)> {
        self.map.iter().map(|(&k, &l)| (k, l)).collect()
    }

    /// Physical location of a live record, for tests and tooling.
    pub fn locate(&self, key: u64) -> Option<(PathBuf, u64, u32)> {
        let loc = self.map.get(&key)?;
        Some((self.segment_path(loc.seg), loc.offset, loc.len))
    }

    /// Serve a record: `None` for an absent key, `Some(Err(_))` when the
    /// stored bytes fail validation — in which case the entry is dropped
    /// so the point degrades to a self-healing miss instead of erroring
    /// forever.
    pub fn lookup_result(&mut self, key: u64) -> Option<Result<RunResult>> {
        let loc = *self.map.get(&key)?;
        match self.read_checked(key, loc, |rec| decode_result_bin(rec.payload)) {
            Ok(r) => Some(Ok(r)),
            Err(e) => {
                self.map.remove(&key);
                self.dirty = true;
                Some(Err(e))
            }
        }
    }

    /// Append a result under its point key. One unbuffered write per
    /// record: every append is immediately visible to concurrently-open
    /// stores, and a torn write can only damage the final record, which
    /// the next scan seals off.
    pub fn append_result(&mut self, key: u64, stamp: u64, r: &RunResult) -> Result<()> {
        self.append_payload(key, stamp, &encode_result_bin(r))
    }

    /// Raw `(stamp, payload)` of a live record, for merge tooling. Same
    /// degradation contract as [`SegmentStore::lookup_result`]: a record
    /// that fails validation is dropped (`Some(Err(_))`) so the key
    /// heals to a miss.
    pub(crate) fn read_raw(&mut self, key: u64) -> Option<Result<(u64, Vec<u8>)>> {
        let loc = *self.map.get(&key)?;
        match self.read_checked(key, loc, |rec| Ok((rec.stamp, rec.payload.to_vec()))) {
            Ok(v) => Some(Ok(v)),
            Err(e) => {
                self.map.remove(&key);
                self.dirty = true;
                Some(Err(e))
            }
        }
    }

    pub(crate) fn append_payload(&mut self, key: u64, stamp: u64, payload: &[u8]) -> Result<()> {
        self.ensure_writer()?;
        let rec = encode_record(key, stamp, payload);
        let (id, offset) = {
            let w = self.writer.as_ref().expect("ensure_writer left a writer");
            (w.id, w.len)
        };
        let path = self.segment_path(id);
        let append = {
            let io = &self.io;
            with_retry(|| io.append(&path, &rec))
        };
        if let Err(e) = append {
            // The failed call may still have landed a prefix of the
            // frame (a torn write). Seal the segment so nothing ever
            // appends after the suspect bytes; the next append rolls to
            // a fresh segment, and a reopen's scan confirms the seal.
            self.writer = None;
            let refreshed = self.io.file_len(&path).ok();
            if let Some(meta) = self.segments.get_mut(&id) {
                meta.sealed = true;
                if let Some(len) = refreshed {
                    meta.len = len;
                }
            }
            self.dirty = true;
            return Err(e.into());
        }
        let w = self.writer.as_mut().expect("writer survives a successful append");
        w.len += rec.len() as u64;
        let new_len = w.len;
        if new_len >= self.roll_bytes {
            self.writer = None;
        }
        let meta = self.segments.get_mut(&id).expect("writer segment is registered");
        meta.len = new_len;
        meta.covered = new_len;
        self.map.insert(key, Loc { seg: id, offset, len: rec.len() as u32, stamp });
        self.dirty = true;
        Ok(())
    }

    /// Drop a key from the index. The record bytes stay until the next
    /// compaction — and until then a rebuild-from-scan would resurrect
    /// the entry, which is safe for a cache (it can only re-serve what a
    /// simulation would recompute).
    pub fn remove(&mut self, key: u64) -> bool {
        let hit = self.map.remove(&key).is_some();
        if hit {
            self.dirty = true;
        }
        hit
    }

    /// Rewrite every live record into fresh segments (numbered after the
    /// current maximum) and delete the old files. A kill at any point
    /// leaves a directory [`SegmentStore::open`] recovers: before the
    /// index flush the old segments still hold every record; after it
    /// the orphaned old files are either gone or rediscovered by the
    /// scan as duplicates of the rewritten entries.
    pub fn compact(&mut self) -> Result<CompactStats> {
        let mut entries: Vec<(u64, Loc)> = self.map.iter().map(|(&k, &l)| (k, l)).collect();
        entries.sort_unstable_by_key(|&(_, l)| (l.seg, l.offset));
        let old_ids: Vec<u32> = self.segments.keys().copied().collect();
        let old_bytes: u64 = self.segments.values().map(|m| m.len).sum();
        self.writer = None;
        self.min_writer_seg = old_ids.last().map_or(0, |&hi| hi + 1);
        let mut stats = CompactStats::default();
        for (key, loc) in entries {
            match self.read_checked(key, loc, |rec| Ok((rec.stamp, rec.payload.to_vec()))) {
                Ok((stamp, payload)) => {
                    self.append_payload(key, stamp, &payload)?;
                    stats.rewritten += 1;
                }
                Err(_) => {
                    self.map.remove(&key);
                    stats.dropped += 1;
                }
            }
        }
        for id in &old_ids {
            self.segments.remove(id);
            self.readers.remove(id);
        }
        self.dirty = true;
        self.flush_index()?;
        for id in &old_ids {
            let _ = self.io.remove_file(&self.segment_path(*id));
        }
        self.min_writer_seg = 0;
        let new_bytes: u64 = self.segments.values().map(|m| m.len).sum();
        stats.reclaimed_bytes = old_bytes.saturating_sub(new_bytes);
        Ok(stats)
    }

    /// Write the index (atomically, tmp + rename) if anything changed.
    pub fn flush_index(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        {
            let io = &self.io;
            with_retry(|| io.create_dir_all(&self.dir))?;
        }
        let mut out = Vec::with_capacity(32 + self.segments.len() * 13 + self.map.len() * 32);
        out.extend_from_slice(&INDEX_MAGIC);
        out.extend_from_slice(&(self.segments.len() as u64).to_le_bytes());
        for (&id, m) in &self.segments {
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&m.covered.to_le_bytes());
            out.push(u8::from(m.sealed));
        }
        out.extend_from_slice(&(self.map.len() as u64).to_le_bytes());
        for (&key, loc) in &self.map {
            out.extend_from_slice(&key.to_le_bytes());
            out.extend_from_slice(&loc.seg.to_le_bytes());
            out.extend_from_slice(&loc.offset.to_le_bytes());
            out.extend_from_slice(&loc.len.to_le_bytes());
            out.extend_from_slice(&loc.stamp.to_le_bytes());
        }
        let sum = fnv64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        let tmp = self.dir.join(format!("{INDEX_FILE}.tmp{}", std::process::id()));
        let dst = self.dir.join(INDEX_FILE);
        let io = &self.io;
        with_retry(|| io.write(&tmp, &out))?;
        with_retry(|| io.rename(&tmp, &dst))?;
        self.dirty = false;
        Ok(())
    }

    /// Validate and read the record at `loc`, handing the parsed frame
    /// to `f`. Zero-copy when the segment is memory-mapped; otherwise
    /// (no mapping, or bytes appended after the mapping was taken) a
    /// positioned read through the I/O seam serves identical bytes.
    fn read_checked<T>(
        &mut self,
        key: u64,
        loc: Loc,
        f: impl FnOnce(&RawRecord<'_>) -> Result<T>,
    ) -> Result<T> {
        let path = self.segment_path(loc.seg);
        if !self.readers.contains_key(&loc.seg) {
            let mapped = self.io.map_segment(&path);
            self.readers.insert(loc.seg, mapped);
        }
        let mapped = self.readers.get(&loc.seg).and_then(|m| m.clone());
        let len = loc.len as usize;
        if let Some(m) = mapped {
            let s = m.as_slice();
            let start = usize::try_from(loc.offset).unwrap_or(usize::MAX);
            if let Some(end) = start.checked_add(len) {
                if end <= s.len() {
                    return check_frame(key, &s[start..end], f);
                }
            }
        }
        let io = &self.io;
        let buf = with_retry(|| io.read_range(&path, loc.offset, len))?;
        check_frame(key, &buf, f)
    }

    /// Make sure `self.writer` targets an appendable segment: the
    /// highest clean, unsealed, unfull one, or a fresh id past both the
    /// maximum and `min_writer_seg`. Bounded roll-forward: a stub left
    /// by a torn magic write is sealed and skipped, never appended to.
    fn ensure_writer(&mut self) -> Result<()> {
        if let Some(w) = &self.writer {
            if w.len < self.roll_bytes {
                return Ok(());
            }
            self.writer = None;
        }
        {
            let io = &self.io;
            with_retry(|| io.create_dir_all(&self.dir))?;
        }
        for _ in 0..4 {
            let reuse = self.segments.iter().next_back().and_then(|(&id, m)| {
                let ok = id >= self.min_writer_seg
                    && !m.sealed
                    && m.covered == m.len
                    && m.len < self.roll_bytes;
                ok.then_some(id)
            });
            let id = reuse.unwrap_or_else(|| {
                let next = self.segments.keys().next_back().map_or(0, |&hi| hi + 1);
                next.max(self.min_writer_seg)
            });
            let path = self.segment_path(id);
            let mut len = match self.io.file_len(&path) {
                Ok(l) => l,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => 0,
                Err(e) => return Err(e.into()),
            };
            if len > 0 && len < SEGMENT_MAGIC.len() as u64 {
                // A torn magic write from an earlier failed provision:
                // records appended after a broken header would be
                // unreachable to a scan. Seal the stub and roll on.
                let meta =
                    self.segments.entry(id).or_insert(SegMeta { len, covered: 0, sealed: false });
                meta.len = len;
                meta.sealed = true;
                self.dirty = true;
                continue;
            }
            if len == 0 {
                let io = &self.io;
                with_retry(|| io.append(&path, &SEGMENT_MAGIC))?;
                len = SEGMENT_MAGIC.len() as u64;
            }
            let meta =
                self.segments.entry(id).or_insert(SegMeta { len: 0, covered: 0, sealed: false });
            meta.len = len;
            meta.covered = len;
            self.writer = Some(SegmentWriter { id, len });
            return Ok(());
        }
        Err(crate::format_err!(
            "segment store: could not provision a writable segment under {}",
            self.dir.display()
        ))
    }
}

/// Validate a full record frame read from `bytes` against the index's
/// expectations (exact frame length, matching key), then hand it to `f`.
fn check_frame<T>(
    key: u64,
    bytes: &[u8],
    f: impl FnOnce(&RawRecord<'_>) -> Result<T>,
) -> Result<T> {
    let (rec, total) = validate_record(bytes)?;
    ensure!(total == bytes.len(), "record frame length disagrees with the index");
    ensure!(rec.key == key, "record key {:#018x} does not match index key {key:#018x}", rec.key);
    f(&rec)
}

/// A validated record frame borrowed from segment bytes.
struct RawRecord<'a> {
    key: u64,
    stamp: u64,
    payload: &'a [u8],
}

pub(crate) fn encode_record(key: u64, stamp: u64, payload: &[u8]) -> Vec<u8> {
    let mut rec =
        Vec::with_capacity(RECORD_HEADER_BYTES + payload.len() + RECORD_TRAILER_BYTES);
    rec.extend_from_slice(&key.to_le_bytes());
    rec.extend_from_slice(&stamp.to_le_bytes());
    rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    rec.extend_from_slice(payload);
    let sum = fnv64(&rec);
    rec.extend_from_slice(&sum.to_le_bytes());
    rec
}

/// Validate one record at the start of `bytes` (which may extend past
/// it); returns the parsed frame plus its total on-disk length. Framing
/// damage of any kind — truncation, implausible length, checksum
/// mismatch — is one recoverable error.
fn validate_record(bytes: &[u8]) -> Result<(RawRecord<'_>, usize)> {
    ensure!(
        bytes.len() >= RECORD_HEADER_BYTES + RECORD_TRAILER_BYTES,
        "record truncated: {} bytes",
        bytes.len()
    );
    let len = u32::from_le_bytes(bytes[16..20].try_into().expect("4 bytes")) as usize;
    ensure!(len <= MAX_PAYLOAD_BYTES, "record payload length {len} implausible");
    let total = RECORD_HEADER_BYTES + len + RECORD_TRAILER_BYTES;
    ensure!(bytes.len() >= total, "record truncated mid-payload");
    let body = &bytes[..RECORD_HEADER_BYTES + len];
    let want =
        u64::from_le_bytes(bytes[RECORD_HEADER_BYTES + len..total].try_into().expect("8 bytes"));
    ensure!(fnv64(body) == want, "record checksum mismatch");
    let key = u64::from_le_bytes(bytes[0..8].try_into().expect("8 bytes"));
    let stamp = u64::from_le_bytes(bytes[8..16].try_into().expect("8 bytes"));
    let payload = &bytes[RECORD_HEADER_BYTES..RECORD_HEADER_BYTES + len];
    Ok((RawRecord { key, stamp, payload }, total))
}

struct Scan {
    entries: Vec<(u64, Loc)>,
    covered: u64,
    clean: bool,
}

/// Walk records from byte `from` (0 = validate the magic first) to the
/// end of the segment. Stops at the first invalid record: everything
/// before it is trusted, everything after is unreachable garbage the
/// caller seals off.
fn scan_segment(io: &dyn StoreIo, path: &Path, id: u32, from: u64) -> Scan {
    let Ok(bytes) = with_retry(|| io.read(path)) else {
        return Scan { entries: Vec::new(), covered: from, clean: false };
    };
    let mut off = from as usize;
    if off == 0 {
        if bytes.len() < SEGMENT_MAGIC.len() || bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
            return Scan { entries: Vec::new(), covered: 0, clean: false };
        }
        off = SEGMENT_MAGIC.len();
    }
    let mut entries = Vec::new();
    loop {
        if off >= bytes.len() {
            return Scan { entries, covered: off as u64, clean: true };
        }
        match validate_record(&bytes[off..]) {
            Ok((rec, total)) => {
                entries.push((
                    rec.key,
                    Loc { seg: id, offset: off as u64, len: total as u32, stamp: rec.stamp },
                ));
                off += total;
            }
            Err(_) => return Scan { entries, covered: off as u64, clean: false },
        }
    }
}

struct IndexContents {
    segs: Vec<(u32, u64, bool)>,
    entries: Vec<(u64, Loc)>,
}

/// Strictly parse the index file. `Ok(None)` when absent; any anomaly —
/// bad checksum, bad magic, truncation, trailing bytes — is an `Err`
/// the caller answers with a full rescan.
fn load_index(io: &dyn StoreIo, path: &Path) -> Result<Option<IndexContents>> {
    let bytes = match with_retry(|| io.read(path)) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    ensure!(bytes.len() >= INDEX_MAGIC.len() + 8, "index truncated");
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(sum_bytes.try_into().expect("8 bytes"));
    ensure!(fnv64(body) == want, "index checksum mismatch");
    let mut cur = Cursor { bytes: body, at: 0 };
    ensure!(cur.take(INDEX_MAGIC.len())? == &INDEX_MAGIC[..], "index magic mismatch");
    let n_segs = cur.u64()?;
    let mut segs = Vec::new();
    for _ in 0..n_segs {
        segs.push((cur.u32()?, cur.u64()?, cur.u8()? != 0));
    }
    let n_entries = cur.u64()?;
    let mut entries = Vec::with_capacity(usize::try_from(n_entries).unwrap_or(0).min(1 << 24));
    for _ in 0..n_entries {
        let key = cur.u64()?;
        let seg = cur.u32()?;
        let offset = cur.u64()?;
        let len = cur.u32()?;
        let stamp = cur.u64()?;
        entries.push((key, Loc { seg, offset, len, stamp }));
    }
    ensure!(cur.at == body.len(), "index has trailing bytes");
    Ok(Some(IndexContents { segs, entries }))
}

struct Cursor<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.bytes.len() - self.at >= n, "index truncated");
        let s = &self.bytes[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("multistride_seg_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn payload(i: u64) -> Vec<u8> {
        (0..48u64).map(|j| (i.wrapping_mul(31).wrapping_add(j) & 0xFF) as u8).collect()
    }

    #[test]
    fn record_frame_roundtrips_and_rejects_tampering() {
        let rec = encode_record(0xAB, 1234, &payload(7));
        let (raw, total) = validate_record(&rec).expect("valid");
        assert_eq!((raw.key, raw.stamp, total), (0xAB, 1234, rec.len()));
        assert_eq!(raw.payload, &payload(7)[..]);
        for cut in [0, 1, RECORD_HEADER_BYTES, rec.len() - 1] {
            assert!(validate_record(&rec[..cut]).is_err(), "cut at {cut}");
        }
        for flip in [0, 8, 16, RECORD_HEADER_BYTES + 3, rec.len() - 1] {
            let mut bad = rec.clone();
            bad[flip] ^= 0x40;
            assert!(validate_record(&bad).is_err(), "flip at {flip}");
        }
    }

    #[test]
    fn scan_recovers_without_index_and_truncation_seals_the_tail() {
        let dir = test_dir("scan");
        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        for i in 0..5u64 {
            st.append_payload(i, 100 + i, &payload(i)).unwrap();
        }
        let (seg_path, ..) = st.locate(0).unwrap();
        drop(st); // no flush_index call: recovery must come from the scan

        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        assert!(!st.index_loaded());
        assert_eq!((st.entry_count(), st.take_open_corruption()), (5, 0));

        // Tear the final record: earlier records survive, the segment is
        // sealed, and the next append rolls to a fresh segment.
        let bytes = fs::read(&seg_path).unwrap();
        fs::write(&seg_path, &bytes[..bytes.len() - 5]).unwrap();
        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        assert_eq!((st.entry_count(), st.take_open_corruption()), (4, 1));
        assert_eq!(st.sealed_count(), 1);
        assert!(st.locate(4).is_none());
        st.append_payload(4, 104, &payload(4)).unwrap();
        let (new_seg, ..) = st.locate(4).unwrap();
        assert_ne!(new_seg, seg_path, "writer must not touch a sealed segment");
        assert_eq!(st.entry_count(), 5);

        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn index_roundtrip_and_corrupt_index_fall_back_to_scan() {
        let dir = test_dir("index");
        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        for i in 0..6u64 {
            st.append_payload(i, i, &payload(i)).unwrap();
        }
        st.flush_index().unwrap();
        let want = {
            let mut e = st.entries();
            e.sort_unstable();
            e
        };
        drop(st);

        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        assert!(st.index_loaded());
        assert_eq!(st.take_open_corruption(), 0);
        let mut got = st.entries();
        got.sort_unstable();
        assert_eq!(got, want);

        // Any damage to the index byte stream must fall back to a scan
        // that re-derives the identical entries.
        let idx = dir.join(INDEX_FILE);
        let mut bytes = fs::read(&idx).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        fs::write(&idx, &bytes).unwrap();
        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        assert!(!st.index_loaded());
        assert_eq!(st.take_open_corruption(), 1);
        let mut got = st.entries();
        got.sort_unstable();
        assert_eq!(got, want);

        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn small_roll_size_spreads_records_across_segments() {
        let dir = test_dir("roll");
        let mut st = SegmentStore::open(&dir, 200);
        for i in 0..8u64 {
            st.append_payload(i, i, &payload(i)).unwrap();
        }
        assert!(st.segment_count() >= 3, "roll=200 must split 8 × ~76-byte records");
        assert_eq!(st.entry_count(), 8);
        drop(st);
        let st = SegmentStore::open(&dir, 200);
        assert_eq!(st.entry_count(), 8);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_append_stops_cleanly_and_recovery_keeps_earlier_records() {
        use super::super::vfs::{FaultIo, FaultPlan, RealIo};
        let dir = test_dir("failappend");
        let fault = Arc::new(FaultIo::new(Arc::new(RealIo), FaultPlan::crash_after(12)));
        let mut st = SegmentStore::open_with(&dir, DEFAULT_ROLL_BYTES, fault);
        let mut ok = 0u64;
        let mut failed = false;
        for i in 0..64u64 {
            match st.append_payload(i, i, &payload(i)) {
                Ok(()) => ok += 1,
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        assert!(failed, "the crash-point must surface as an append error");
        assert!(ok > 0, "some appends land before the crash-point");
        drop(st);

        // Reopen on the real filesystem: every pre-crash record serves
        // its exact bytes back.
        let mut st = SegmentStore::open(&dir, DEFAULT_ROLL_BYTES);
        assert_eq!(st.entry_count(), ok);
        for i in 0..ok {
            let loc = *st.map.get(&i).expect("pre-crash record survives");
            let got = st.read_checked(i, loc, |rec| Ok(rec.payload.to_vec())).unwrap();
            assert_eq!(got, payload(i));
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_reclaims_removed_records_and_survives_reopen() {
        let dir = test_dir("compact");
        let mut st = SegmentStore::open(&dir, 256);
        for i in 0..10u64 {
            st.append_payload(i, i, &payload(i)).unwrap();
        }
        for i in 0..5u64 {
            assert!(st.remove(i * 2));
        }
        let before = st.segment_bytes();
        let stats = st.compact().unwrap();
        assert_eq!((stats.rewritten, stats.dropped), (5, 0));
        assert!(stats.reclaimed_bytes > 0);
        assert!(st.segment_bytes() < before);
        drop(st);

        let mut st = SegmentStore::open(&dir, 256);
        assert!(st.index_loaded());
        assert_eq!(st.entry_count(), 5);
        for i in 0..10u64 {
            assert_eq!(st.contains(i), i % 2 == 1, "key {i}");
        }
        // The compacted bytes must still validate end to end.
        for i in [1u64, 3, 5, 7, 9] {
            let loc = *st.map.get(&i).unwrap();
            let got = st.read_checked(i, loc, |rec| Ok(rec.payload.to_vec())).unwrap();
            assert_eq!(got, payload(i));
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
