//! The execution layer: every experiment is a set of content-addressed
//! [`SimPoint`] jobs resolved through a deduplicating [`ResultStore`].
//!
//! Before this layer, each entry point (figure drivers, `universe`, the
//! tuner's cost model, benches) hand-rolled its own simulate-points loop,
//! so a `repro all` run re-simulated identical `(workload, machine,
//! prefetch, budget)` points many times, and nothing except the tuner's
//! winner-only plan cache survived a process exit. The paper's whole
//! methodology is a large grid of *deterministic* simulations; this
//! module makes the grid incremental:
//!
//! * [`point`] — the [`SimPoint`] job and its FNV content key (spec
//!   content hash × variant × machine fingerprint × prefetch ×
//!   translation regime), built on the tuner's identity machinery.
//! * [`store`] — the [`ResultStore`]: an in-memory tier for in-process
//!   reuse plus a persistent tier under `<artifacts>/results/`, packed
//!   into append-only segment files (legacy PR-5 file-per-point shards
//!   stay readable as a fallback). Exposes [`ExecStats`] so runs can
//!   report their hit/dedup economy.
//! * [`segment`] — the segment tier itself: checksummed record frames,
//!   the rebuildable `index.msidx`, memory-mapped reads (default-on
//!   `mmap` feature) with a positioned-read fallback, and compaction.
//! * [`format`] — the bit-exact `multistride-simresult v1` text format
//!   and its fixed-width binary twin (the segment record payload).
//! * [`planner`] — [`Planner`]: batch dedup + scheduling over the
//!   existing warm-engine worker pool, and [`simulate`], the single
//!   place a point becomes an engine run.
//! * [`lifecycle`] — directory-wide tooling behind `repro store
//!   {stats,gc,verify,compact}`: stats, bounded eviction, the
//!   re-simulate-and-compare verification sweep, and compaction (which
//!   also folds legacy shards into segments).
//! * [`vfs`] — the [`vfs::StoreIo`] seam every filesystem touch goes
//!   through: the real impl, the bounded retry policy, and the seeded
//!   fault injector the chaos wall (`tests/chaos_store.rs`) drives.
//! * [`grid`] — sharded grid execution (`repro grid --shard k/n`):
//!   deterministic key-range partitioning, checksummed shard-ownership
//!   manifests, and the conflict-quarantining `repro store merge`.
//!
//! Consumers (`coordinator::experiments`, `tune::cost`) are thin
//! plan-builders and result-formatters around this layer; the CLI picks
//! the store (`--results DIR`, `--cold`) and prints the stats summary.
//! Correctness rests on determinism: a store hit must be bit-identical
//! to a fresh simulation, and debug builds re-simulate every hit to
//! assert exactly that. See ARCHITECTURE.md §Execution layer.

pub mod format;
pub mod grid;
pub mod lifecycle;
pub mod planner;
pub mod point;
pub mod segment;
pub mod store;
pub mod vfs;

pub use planner::{simulate, Planner};
pub use point::{SimPoint, Workload, SIM_REVISION};
pub use store::{ExecStats, ResultStore};
