//! Bit-exact on-disk format for stored simulation results
//! (`multistride-simresult v1`).
//!
//! Same discipline as the tuner's plan format ([`crate::tune::plan`]),
//! whose field-walk helpers this module reuses: a fixed header line, a
//! fixed-order `key = value` block, and a terminating FNV-1a `checksum`
//! line over every preceding byte. Integers are decimal `u64`s; the one
//! float ([`RunResult::freq_ghz`]) is serialized as its IEEE-754 bit
//! pattern so serialize → parse → serialize is **bit-identical** — the
//! property that lets a store hit stand in for a fresh simulation and
//! lets the debug-build verification compare serialized bytes.
//!
//! The first field is the owning [`super::SimPoint`] key: a result file
//! that was renamed, copied between shards, or otherwise detached from
//! its key fails the load-time identity check and degrades to a miss —
//! the same can-never-smuggle-a-stale-entry stance the plan cache takes.
//!
//! The module also defines the format's fixed-width **binary twin**
//! ([`encode_result_bin`] / [`decode_result_bin`]): the same fields in
//! the same order, each as a little-endian `u64`. It is the payload the
//! segment tier ([`super::segment`]) packs — decoding is a bounds check
//! plus [`RESULT_BIN_FIELDS`] byte-copies, so a memory-mapped store hit
//! never walks text. Both encodings reconstruct the identical
//! [`RunResult`] (`tests/result_store_roundtrip.rs` cross-checks them
//! through re-serialization); the text form remains the interchange and
//! legacy file-per-point representation.

use crate::sim::RunResult;
use crate::tune::plan::{expect_field, fnv64, hex, parse_f64, parse_u64};
use crate::{ensure, format_err, Result};

/// First line of every result file; doubles as the format version. Bump
/// on any field change — old files then fail the header check, which is
/// a miss (re-simulate), the intended migration path.
pub const RESULT_HEADER: &str = "multistride-simresult v1";

/// Serialize a result under its owning point key.
pub fn serialize_result(key: u64, r: &RunResult) -> String {
    fn kv(out: &mut String, k: &str, v: impl std::fmt::Display) {
        use std::fmt::Write;
        let _ = writeln!(out, "{k} = {v}");
    }
    let mut out = String::with_capacity(1536);
    out.push_str(RESULT_HEADER);
    out.push('\n');
    kv(&mut out, "point_key", hex(key));
    let c = &r.counters;
    kv(&mut out, "cycles", c.cycles);
    kv(&mut out, "stalls_total", c.stalls_total);
    kv(&mut out, "stalls_mem_any", c.stalls_mem_any);
    kv(&mut out, "stalls_l1d_miss", c.stalls_l1d_miss);
    kv(&mut out, "stalls_l2_miss", c.stalls_l2_miss);
    kv(&mut out, "stalls_l3_miss", c.stalls_l3_miss);
    kv(&mut out, "accesses", c.accesses);
    kv(&mut out, "bytes_read", c.bytes_read);
    kv(&mut out, "bytes_written", c.bytes_written);
    kv(&mut out, "dram_demand_lines", c.dram_demand_lines);
    kv(&mut out, "prefetch_lines", c.prefetch_lines);
    kv(&mut out, "prefetch_merges", c.prefetch_merges);
    kv(&mut out, "tlb_cycles", c.tlb_cycles);
    for (tag, s) in [("l1", &r.l1), ("l2", &r.l2), ("l3", &r.l3)] {
        kv(&mut out, &format!("{tag}_demand_hits"), s.demand_hits);
        kv(&mut out, &format!("{tag}_demand_misses"), s.demand_misses);
        kv(&mut out, &format!("{tag}_prefetch_hits"), s.prefetch_hits);
        kv(&mut out, &format!("{tag}_evictions"), s.evictions);
        kv(&mut out, &format!("{tag}_dirty_evictions"), s.dirty_evictions);
        kv(&mut out, &format!("{tag}_unused_prefetch_evictions"), s.unused_prefetch_evictions);
        kv(&mut out, &format!("{tag}_prefetch_installs"), s.prefetch_installs);
    }
    kv(&mut out, "dram_reads", r.dram.reads);
    kv(&mut out, "dram_writes", r.dram.writes);
    kv(&mut out, "dram_row_hits", r.dram.row_hits);
    kv(&mut out, "dram_row_misses", r.dram.row_misses);
    kv(&mut out, "dram_busy_cycles", r.dram.busy_cycles);
    kv(&mut out, "wc_stores", r.wc.stores);
    kv(&mut out, "wc_full_flushes", r.wc.full_flushes);
    kv(&mut out, "wc_partial_flushes", r.wc.partial_flushes);
    kv(&mut out, "tlb_accesses", r.tlb.accesses);
    kv(&mut out, "tlb_l1_misses", r.tlb.l1_misses);
    kv(&mut out, "tlb_walks", r.tlb.walks);
    kv(&mut out, "streamer_observations", r.streamer.observations);
    kv(&mut out, "streamer_streams_allocated", r.streamer.streams_allocated);
    kv(&mut out, "streamer_streams_evicted", r.streamer.streams_evicted);
    kv(&mut out, "streamer_streams_evicted_untrained", r.streamer.streams_evicted_untrained);
    kv(&mut out, "streamer_prefetches_issued", r.streamer.prefetches_issued);
    kv(&mut out, "streamer_page_carries", r.streamer.page_carries);
    kv(&mut out, "freq_ghz", hex(r.freq_ghz.to_bits()));
    let sum = fnv64(out.as_bytes());
    kv(&mut out, "checksum", hex(sum));
    out
}

/// Parse the on-disk format back into `(point key, result)`. Checksum is
/// verified first (one clear error for any corruption or truncation),
/// then the strict fixed-order field walk. Never panics on bad input.
pub fn parse_result(text: &str) -> Result<(u64, RunResult)> {
    let idx = text
        .rfind("checksum = ")
        .ok_or_else(|| format_err!("result corrupt: no checksum line (truncated?)"))?;
    ensure!(
        idx == 0 || text[..idx].ends_with('\n'),
        "result corrupt: checksum marker not at line start"
    );
    let prefix = &text[..idx];
    let val = text[idx..].strip_prefix("checksum = ").expect("rfind guarantees the prefix");
    let val = val
        .strip_suffix('\n')
        .ok_or_else(|| format_err!("result corrupt: checksum line not newline-terminated"))?;
    let want = parse_u64(val)?;
    ensure!(val == hex(want), "result corrupt: checksum line not in canonical form");
    ensure!(
        fnv64(prefix.as_bytes()) == want,
        "result corrupt: checksum mismatch (file edited or truncated)"
    );

    let mut lines = prefix.lines();
    ensure!(
        lines.next() == Some(RESULT_HEADER),
        "result corrupt or wrong version: expected header {RESULT_HEADER:?}"
    );
    let key = parse_u64(expect_field(&mut lines, "point_key")?)?;
    let mut next_u64 = |field: &str| -> Result<u64> { parse_u64(expect_field(&mut lines, field)?) };
    let counters = crate::sim::Counters {
        cycles: next_u64("cycles")?,
        stalls_total: next_u64("stalls_total")?,
        stalls_mem_any: next_u64("stalls_mem_any")?,
        stalls_l1d_miss: next_u64("stalls_l1d_miss")?,
        stalls_l2_miss: next_u64("stalls_l2_miss")?,
        stalls_l3_miss: next_u64("stalls_l3_miss")?,
        accesses: next_u64("accesses")?,
        bytes_read: next_u64("bytes_read")?,
        bytes_written: next_u64("bytes_written")?,
        dram_demand_lines: next_u64("dram_demand_lines")?,
        prefetch_lines: next_u64("prefetch_lines")?,
        prefetch_merges: next_u64("prefetch_merges")?,
        tlb_cycles: next_u64("tlb_cycles")?,
    };
    let mut cache_stats = |tag: &str| -> Result<crate::mem::cache::CacheStats> {
        Ok(crate::mem::cache::CacheStats {
            demand_hits: next_u64(&format!("{tag}_demand_hits"))?,
            demand_misses: next_u64(&format!("{tag}_demand_misses"))?,
            prefetch_hits: next_u64(&format!("{tag}_prefetch_hits"))?,
            evictions: next_u64(&format!("{tag}_evictions"))?,
            dirty_evictions: next_u64(&format!("{tag}_dirty_evictions"))?,
            unused_prefetch_evictions: next_u64(&format!("{tag}_unused_prefetch_evictions"))?,
            prefetch_installs: next_u64(&format!("{tag}_prefetch_installs"))?,
        })
    };
    let l1 = cache_stats("l1")?;
    let l2 = cache_stats("l2")?;
    let l3 = cache_stats("l3")?;
    let dram = crate::mem::dram::DramStats {
        reads: next_u64("dram_reads")?,
        writes: next_u64("dram_writes")?,
        row_hits: next_u64("dram_row_hits")?,
        row_misses: next_u64("dram_row_misses")?,
        busy_cycles: next_u64("dram_busy_cycles")?,
    };
    let wc = crate::mem::writebuffer::WcStats {
        stores: next_u64("wc_stores")?,
        full_flushes: next_u64("wc_full_flushes")?,
        partial_flushes: next_u64("wc_partial_flushes")?,
    };
    let tlb = crate::mem::tlb::TlbStats {
        accesses: next_u64("tlb_accesses")?,
        l1_misses: next_u64("tlb_l1_misses")?,
        walks: next_u64("tlb_walks")?,
    };
    let streamer = crate::prefetch::streamer::StreamerStats {
        observations: next_u64("streamer_observations")?,
        streams_allocated: next_u64("streamer_streams_allocated")?,
        streams_evicted: next_u64("streamer_streams_evicted")?,
        streams_evicted_untrained: next_u64("streamer_streams_evicted_untrained")?,
        prefetches_issued: next_u64("streamer_prefetches_issued")?,
        page_carries: next_u64("streamer_page_carries")?,
    };
    let freq_ghz = parse_f64(expect_field(&mut lines, "freq_ghz")?)?;
    ensure!(lines.next().is_none(), "result corrupt: trailing content after the field block");
    Ok((key, RunResult { counters, l1, l2, l3, dram, wc, tlb, streamer, freq_ghz }))
}

/// Number of `u64` words in the binary encoding: the 13 core counters,
/// 3 × 7 cache levels, 5 DRAM, 3 write-combining, 3 TLB, 6 streamer
/// fields, and `freq_ghz` as its bit pattern. Mirrors the field order of
/// [`serialize_result`] exactly.
pub const RESULT_BIN_FIELDS: usize = 52;

/// Byte length of the fixed-width binary encoding.
pub const RESULT_BIN_BYTES: usize = RESULT_BIN_FIELDS * 8;

/// The 52 field values in [`serialize_result`] order. Single source of
/// truth for the binary layout: encode writes these words, decode reads
/// them back positionally.
fn field_words(r: &RunResult) -> [u64; RESULT_BIN_FIELDS] {
    let c = &r.counters;
    [
        c.cycles,
        c.stalls_total,
        c.stalls_mem_any,
        c.stalls_l1d_miss,
        c.stalls_l2_miss,
        c.stalls_l3_miss,
        c.accesses,
        c.bytes_read,
        c.bytes_written,
        c.dram_demand_lines,
        c.prefetch_lines,
        c.prefetch_merges,
        c.tlb_cycles,
        r.l1.demand_hits,
        r.l1.demand_misses,
        r.l1.prefetch_hits,
        r.l1.evictions,
        r.l1.dirty_evictions,
        r.l1.unused_prefetch_evictions,
        r.l1.prefetch_installs,
        r.l2.demand_hits,
        r.l2.demand_misses,
        r.l2.prefetch_hits,
        r.l2.evictions,
        r.l2.dirty_evictions,
        r.l2.unused_prefetch_evictions,
        r.l2.prefetch_installs,
        r.l3.demand_hits,
        r.l3.demand_misses,
        r.l3.prefetch_hits,
        r.l3.evictions,
        r.l3.dirty_evictions,
        r.l3.unused_prefetch_evictions,
        r.l3.prefetch_installs,
        r.dram.reads,
        r.dram.writes,
        r.dram.row_hits,
        r.dram.row_misses,
        r.dram.busy_cycles,
        r.wc.stores,
        r.wc.full_flushes,
        r.wc.partial_flushes,
        r.tlb.accesses,
        r.tlb.l1_misses,
        r.tlb.walks,
        r.streamer.observations,
        r.streamer.streams_allocated,
        r.streamer.streams_evicted,
        r.streamer.streams_evicted_untrained,
        r.streamer.prefetches_issued,
        r.streamer.page_carries,
        r.freq_ghz.to_bits(),
    ]
}

/// Encode a result as [`RESULT_BIN_BYTES`] little-endian bytes. The
/// point key is NOT part of the payload — the segment record frame
/// carries it ([`super::segment`]), keeping the key check in the framing
/// layer where the checksum lives.
pub fn encode_result_bin(r: &RunResult) -> [u8; RESULT_BIN_BYTES] {
    let mut out = [0u8; RESULT_BIN_BYTES];
    for (slot, word) in out.chunks_exact_mut(8).zip(field_words(r)) {
        slot.copy_from_slice(&word.to_le_bytes());
    }
    out
}

/// Decode the fixed-width binary encoding. Only the length is validated
/// here — integrity is the framing checksum's job, which callers verify
/// before decoding. Never panics on bad input.
pub fn decode_result_bin(bytes: &[u8]) -> Result<RunResult> {
    ensure!(
        bytes.len() == RESULT_BIN_BYTES,
        "binary result corrupt: {} bytes, expected {RESULT_BIN_BYTES}",
        bytes.len()
    );
    let mut words = bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().expect("chunks_exact yields 8-byte slices")));
    let mut next = move || words.next().expect("length checked above");
    let counters = crate::sim::Counters {
        cycles: next(),
        stalls_total: next(),
        stalls_mem_any: next(),
        stalls_l1d_miss: next(),
        stalls_l2_miss: next(),
        stalls_l3_miss: next(),
        accesses: next(),
        bytes_read: next(),
        bytes_written: next(),
        dram_demand_lines: next(),
        prefetch_lines: next(),
        prefetch_merges: next(),
        tlb_cycles: next(),
    };
    let mut cache_stats = || crate::mem::cache::CacheStats {
        demand_hits: next(),
        demand_misses: next(),
        prefetch_hits: next(),
        evictions: next(),
        dirty_evictions: next(),
        unused_prefetch_evictions: next(),
        prefetch_installs: next(),
    };
    let (l1, l2, l3) = (cache_stats(), cache_stats(), cache_stats());
    let dram = crate::mem::dram::DramStats {
        reads: next(),
        writes: next(),
        row_hits: next(),
        row_misses: next(),
        busy_cycles: next(),
    };
    let wc = crate::mem::writebuffer::WcStats {
        stores: next(),
        full_flushes: next(),
        partial_flushes: next(),
    };
    let tlb = crate::mem::tlb::TlbStats {
        accesses: next(),
        l1_misses: next(),
        walks: next(),
    };
    let streamer = crate::prefetch::streamer::StreamerStats {
        observations: next(),
        streams_allocated: next(),
        streams_evicted: next(),
        streams_evicted_untrained: next(),
        prefetches_issued: next(),
        page_carries: next(),
    };
    let freq_ghz = f64::from_bits(next());
    Ok(RunResult { counters, l1, l2, l3, dram, wc, tlb, streamer, freq_ghz })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A result with every field distinct (catches swapped-field bugs)
    /// plus boundary values on the extremes.
    pub(crate) fn sample_result() -> RunResult {
        let mut n = 100u64;
        let mut next = || {
            n += 1;
            n
        };
        let counters = crate::sim::Counters {
            cycles: next(),
            stalls_total: next(),
            stalls_mem_any: next(),
            stalls_l1d_miss: next(),
            stalls_l2_miss: next(),
            stalls_l3_miss: next(),
            accesses: next(),
            bytes_read: next(),
            bytes_written: u64::MAX,
            dram_demand_lines: next(),
            prefetch_lines: next(),
            prefetch_merges: 0,
            tlb_cycles: next(),
        };
        let mut cache = || crate::mem::cache::CacheStats {
            demand_hits: next(),
            demand_misses: next(),
            prefetch_hits: next(),
            evictions: next(),
            dirty_evictions: next(),
            unused_prefetch_evictions: next(),
            prefetch_installs: next(),
        };
        let (l1, l2, l3) = (cache(), cache(), cache());
        RunResult {
            counters,
            l1,
            l2,
            l3,
            dram: crate::mem::dram::DramStats {
                reads: next(),
                writes: next(),
                row_hits: next(),
                row_misses: next(),
                busy_cycles: next(),
            },
            wc: crate::mem::writebuffer::WcStats {
                stores: next(),
                full_flushes: next(),
                partial_flushes: next(),
            },
            tlb: crate::mem::tlb::TlbStats {
                accesses: next(),
                l1_misses: next(),
                walks: next(),
            },
            streamer: crate::prefetch::streamer::StreamerStats {
                observations: next(),
                streams_allocated: next(),
                streams_evicted: next(),
                streams_evicted_untrained: next(),
                prefetches_issued: next(),
                page_carries: next(),
            },
            freq_ghz: 3.2,
        }
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let r = sample_result();
        let s = serialize_result(0xDEAD_BEEF_0123_4567, &r);
        let (key, q) = parse_result(&s).expect("parses");
        assert_eq!(key, 0xDEAD_BEEF_0123_4567);
        assert_eq!(s, serialize_result(key, &q));
    }

    #[test]
    fn nan_and_inf_freq_survive_the_bits_encoding() {
        for f in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.0] {
            let mut r = sample_result();
            r.freq_ghz = f;
            let s = serialize_result(7, &r);
            let (_, q) = parse_result(&s).unwrap();
            assert_eq!(q.freq_ghz.to_bits(), f.to_bits());
            assert_eq!(s, serialize_result(7, &q));
        }
    }

    #[test]
    fn binary_twin_reconstructs_the_exact_text_serialization() {
        let r = sample_result();
        let bin = encode_result_bin(&r);
        assert_eq!(bin.len(), RESULT_BIN_BYTES);
        let q = decode_result_bin(&bin).expect("decodes");
        assert_eq!(serialize_result(7, &r), serialize_result(7, &q));
        // Distinct-valued sample: any field swap or offset slip in the
        // binary layout shows up as a serialization mismatch above, and
        // re-encoding must be byte-identical.
        assert_eq!(bin, encode_result_bin(&q));
    }

    #[test]
    fn binary_decode_rejects_wrong_lengths_and_preserves_nan_bits() {
        let bin = encode_result_bin(&sample_result());
        assert!(decode_result_bin(&bin[..RESULT_BIN_BYTES - 1]).is_err());
        assert!(decode_result_bin(&[]).is_err());
        let mut long = bin.to_vec();
        long.push(0);
        assert!(decode_result_bin(&long).is_err());

        let mut r = sample_result();
        r.freq_ghz = f64::from_bits(0x7FF8_0000_DEAD_BEEF); // NaN payload
        let q = decode_result_bin(&encode_result_bin(&r)).unwrap();
        assert_eq!(q.freq_ghz.to_bits(), 0x7FF8_0000_DEAD_BEEF);
    }

    #[test]
    fn truncation_and_edits_are_recoverable_errors() {
        let s = serialize_result(7, &sample_result());
        for cut in [0, 1, RESULT_HEADER.len(), s.len() / 3, s.len() / 2, s.len() - 2] {
            assert!(parse_result(&s[..cut]).is_err(), "cut at {cut}");
        }
        let tampered = s.replace("dram_reads", "dram_rXads");
        assert!(parse_result(&tampered).is_err());
    }
}
