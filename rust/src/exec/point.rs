//! The canonical unit of simulation work: a [`SimPoint`] and its
//! content-addressed key.
//!
//! Every experiment in the repo — micro grids, kernel sweeps, reference
//! models, tuner rungs — ultimately runs *one deterministic simulation*
//! of a workload on a machine. A `SimPoint` captures exactly the inputs
//! that determine that simulation's [`crate::sim::RunResult`], and
//! [`SimPoint::key`] is an FNV-1a content hash over them:
//!
//! * the **workload content** — for kernels the [`spec_hash`] of the
//!   untransformed spec *at the request budget* plus every
//!   [`StridingConfig`] field (so a kernel-library edit or a budget
//!   change that re-sizes extents changes the key); for micro benchmarks
//!   the op / stride-count / byte-size / arrangement tuple;
//! * the **machine fingerprint** — [`machine_fingerprint`] over the full
//!   [`MachineConfig`] and the prefetch enable bit, the same identity the
//!   tuner's plan cache validates against;
//! * the **translation regime** — the huge-pages bit (§4 micro protocol
//!   uses huge pages, §6 kernel protocol does not).
//!
//! Two points with equal keys produce bit-identical results (the
//! simulator is deterministic and the engine-reuse protocol is pinned by
//! `tests/golden_determinism.rs`), which is what lets the
//! [`super::ResultStore`] serve a stored result in place of a fresh
//! simulation — and what the store's debug-build verification re-checks
//! on every hit.
//!
//! Register feasibility is deliberately *not* part of a point: it gates
//! whether a consumer enqueues a point at all (infeasible variants are
//! reported without simulating, as the sweeps always have), not what the
//! simulation would compute. `machine.simd_registers` still feeds the
//! machine fingerprint, so the keying stays conservative.

use crate::config::MachineConfig;
use crate::kernels::library::kernel_by_name;
use crate::kernels::micro::MicroOp;
use crate::kernels::spec::KernelSpec;
use crate::trace::Arrangement;
use crate::transform::StridingConfig;
use crate::tune::plan::{machine_fingerprint, spec_hash, Fnv};
use crate::{format_err, Result};

/// Simulator-behavior revision, salted into every point key. The inputs
/// a key hashes (spec, variant, machine, prefetch, pages) pin *what* is
/// simulated, not *how*: an intentional engine/model change (one that
/// moves the golden oracle) changes results without changing any input.
/// **Bump this constant in the same commit as any such change** — every
/// persisted result then becomes a clean miss, instead of a stale serve
/// in release builds or a verify-hit panic in debug builds.
pub const SIM_REVISION: u64 = 1;

/// What a [`SimPoint`] simulates.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// A §4 micro-benchmark configuration ([`crate::kernels::micro`]).
    Micro { op: MicroOp, strides: u32, bytes: u64, interleaved: bool },
    /// A transformed kernel from the registry universe at `budget` bytes.
    Kernel { name: String, budget: u64, config: StridingConfig },
}

/// One schedulable simulation job: workload × machine × run regime, with
/// its content key computed at construction.
#[derive(Debug, Clone)]
pub struct SimPoint {
    pub machine: MachineConfig,
    pub prefetch: bool,
    pub huge_pages: bool,
    pub workload: Workload,
    key: u64,
}

impl SimPoint {
    /// A micro-benchmark point (the §4 protocol: huge pages on).
    pub fn micro(
        machine: MachineConfig,
        op: MicroOp,
        strides: u32,
        bytes: u64,
        prefetch: bool,
        interleaved: bool,
    ) -> SimPoint {
        let workload = Workload::Micro { op, strides, bytes, interleaved };
        let key = point_key(&machine, prefetch, true, &workload, 0);
        SimPoint { machine, prefetch, huge_pages: true, workload, key }
    }

    /// A kernel-variant point (the §6 protocol: default 4 KiB pages).
    /// Errors on unknown kernel names — the spec must exist to be
    /// content-hashed. Callers that additionally need the transform to
    /// succeed (always, before scheduling) validate that themselves.
    pub fn kernel(
        machine: MachineConfig,
        name: &str,
        budget: u64,
        config: StridingConfig,
        prefetch: bool,
    ) -> Result<SimPoint> {
        let pk = kernel_by_name(name, budget)
            .ok_or_else(|| format_err!("unknown kernel {name}"))?;
        Ok(Self::kernel_from_spec(machine, name, budget, config, prefetch, &pk.spec))
    }

    /// [`SimPoint::kernel`] when the caller already holds the registry
    /// spec (sweep drivers fetch it for transform/feasibility anyway) —
    /// skips the second registry lookup. `spec` must be what
    /// [`kernel_by_name`]`(name, budget)` returns; the key is its
    /// content hash, so a mismatched spec would mis-address the point.
    pub fn kernel_from_spec(
        machine: MachineConfig,
        name: &str,
        budget: u64,
        config: StridingConfig,
        prefetch: bool,
        spec: &KernelSpec,
    ) -> SimPoint {
        let spec = spec_hash(spec);
        let workload = Workload::Kernel { name: name.to_string(), budget, config };
        let key = point_key(&machine, prefetch, false, &workload, spec);
        SimPoint { machine, prefetch, huge_pages: false, workload, key }
    }

    /// The content-addressed identity of this point (see the module docs
    /// for what feeds it).
    pub fn key(&self) -> u64 {
        self.key
    }

    /// Short human-readable label for diagnostics.
    pub fn label(&self) -> String {
        match &self.workload {
            Workload::Micro { op, strides, bytes, interleaved } => format!(
                "micro {} n={strides} {} MiB{}",
                op.label(),
                bytes >> 20,
                if *interleaved { " [interleaved]" } else { "" }
            ),
            Workload::Kernel { name, budget, config } => format!(
                "kernel {name} s={} p={} {} MiB",
                config.stride_unroll,
                config.portion_unroll,
                budget >> 20
            ),
        }
    }
}

/// The key function. `spec` is the kernel spec's content hash (ignored
/// for micro workloads, whose content is fully captured by the enum
/// fields). Discriminants and field order are part of the persistent
/// store format — changing them orphans on-disk results (a safe miss,
/// but a full re-simulation), so extend only by appending.
fn point_key(
    machine: &MachineConfig,
    prefetch: bool,
    huge_pages: bool,
    workload: &Workload,
    spec: u64,
) -> u64 {
    let mut h = Fnv::new();
    h.u64(SIM_REVISION);
    h.u64(machine_fingerprint(machine, prefetch));
    h.bytes(&[huge_pages as u8]);
    match workload {
        Workload::Micro { op, strides, bytes, interleaved } => {
            h.u64(0);
            h.u64(micro_op_code(*op));
            h.u64(*strides as u64);
            h.u64(*bytes);
            h.bytes(&[*interleaved as u8]);
        }
        Workload::Kernel { name: _, budget: _, config } => {
            // The spec content hash covers the kernel name and every
            // extent the budget produced; the exact byte budget is
            // deliberately absent so budgets that round to the same spec
            // share one entry (their traces are identical).
            h.u64(1);
            h.u64(spec);
            h.u64(config.stride_unroll as u64);
            h.u64(config.portion_unroll as u64);
            h.bytes(&[config.eliminate_redundant as u8]);
            h.u64(match config.arrangement {
                Arrangement::Grouped => 0,
                Arrangement::Interleaved => 1,
            });
        }
    }
    h.finish()
}

/// Stable code per micro op (enum discriminants are not a persistence
/// contract; this mapping is).
fn micro_op_code(op: MicroOp) -> u64 {
    match op {
        MicroOp::LoadAligned => 0,
        MicroOp::LoadUnaligned => 1,
        MicroOp::LoadNt => 2,
        MicroOp::StoreAligned => 3,
        MicroOp::StoreUnaligned => 4,
        MicroOp::StoreNt => 5,
        MicroOp::CopyAligned => 6,
        MicroOp::CopyNt => 7,
        MicroOp::CopyNtBoth => 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{cascade_lake, coffee_lake};

    const MIB: u64 = 1 << 20;

    #[test]
    fn micro_keys_separate_every_axis() {
        let m = coffee_lake();
        let base = SimPoint::micro(m, MicroOp::LoadAligned, 4, 8 * MIB, true, false);
        let same = SimPoint::micro(m, MicroOp::LoadAligned, 4, 8 * MIB, true, false);
        assert_eq!(base.key(), same.key(), "identical content, identical key");
        for other in [
            SimPoint::micro(m, MicroOp::StoreNt, 4, 8 * MIB, true, false),
            SimPoint::micro(m, MicroOp::LoadAligned, 8, 8 * MIB, true, false),
            SimPoint::micro(m, MicroOp::LoadAligned, 4, 16 * MIB, true, false),
            SimPoint::micro(m, MicroOp::LoadAligned, 4, 8 * MIB, false, false),
            SimPoint::micro(m, MicroOp::LoadAligned, 4, 8 * MIB, true, true),
            SimPoint::micro(cascade_lake(), MicroOp::LoadAligned, 4, 8 * MIB, true, false),
        ] {
            assert_ne!(base.key(), other.key(), "{}", other.label());
        }
    }

    #[test]
    fn kernel_keys_track_spec_content_and_variant() {
        let m = coffee_lake();
        let cfg = StridingConfig::new(4, 2);
        let base = SimPoint::kernel(m, "mxv", 8 * MIB, cfg, true).unwrap();
        let same = SimPoint::kernel(m, "mxv", 8 * MIB, cfg, true).unwrap();
        assert_eq!(base.key(), same.key());
        let other_cfg = SimPoint::kernel(m, "mxv", 8 * MIB, StridingConfig::new(2, 2), true)
            .unwrap();
        let other_budget = SimPoint::kernel(m, "mxv", 128 * MIB, cfg, true).unwrap();
        let other_kernel = SimPoint::kernel(m, "bicg", 8 * MIB, cfg, true).unwrap();
        let no_pf = SimPoint::kernel(m, "mxv", 8 * MIB, cfg, false).unwrap();
        assert_ne!(base.key(), other_cfg.key());
        assert_ne!(base.key(), other_budget.key(), "extents feed the spec hash");
        assert_ne!(base.key(), other_kernel.key());
        assert_ne!(base.key(), no_pf.key());
    }

    #[test]
    fn kernel_and_micro_workloads_never_collide_on_tag() {
        // Same machine, same prefetch: the workload tag separates the
        // two key families even under adversarially equal field values.
        let m = coffee_lake();
        let micro = SimPoint::micro(m, MicroOp::LoadAligned, 1, MIB, true, false);
        let kernel =
            SimPoint::kernel(m, "init", MIB, StridingConfig::new(1, 1), true).unwrap();
        assert_ne!(micro.key(), kernel.key());
        assert!(micro.huge_pages && !kernel.huge_pages);
    }

    #[test]
    fn unknown_kernel_is_an_error() {
        assert!(SimPoint::kernel(coffee_lake(), "nope", MIB, StridingConfig::new(1, 1), true)
            .is_err());
    }
}
