//! Sharded grid execution and conflict-free store merge: the library
//! side of `repro grid --shard k/n` and `repro store merge`.
//!
//! The experiment grid is embarrassingly partitionable because every
//! job already has a content key ([`SimPoint::key`]): *partition the
//! key space, not the plan order*. [`shard_of`] maps a key to exactly
//! one of `n` shards by fixed-point range partition — deterministic,
//! total, and independent of how the plan was enumerated, so any two
//! hosts that agree on `n` agree on ownership without coordination.
//!
//! A shard run ([`run_shard`]) simulates only its owned subset and
//! writes a checksummed ownership manifest
//! (`shard-0001-of-0002.manifest`) next to the segment files: magic
//! line, `shard`/`plan_points`/`owned` fields, one sorted `key =` line
//! per owned point, and a trailing FNV-64 checksum over everything
//! above it. The manifest is an audit artifact — merge works on the
//! segment bytes themselves and only *validates* manifests it finds.
//!
//! [`merge`] unions segment directories by content key, idempotent by
//! construction: a record already present with identical payload bytes
//! counts as `already_present` and nothing is written, so re-running a
//! merge is a no-op. Same-key/different-bytes is a **conflict**: the
//! destination copy is kept, the source bytes are quarantined under
//! `<dst>/quarantine/` as a full record frame, and the report turns
//! unclean ([`MergeReport::is_clean`]) — a conflicting byte is never
//! silently chosen, because by the determinism contract it can only
//! mean corruption or a simulator-revision mismatch. Legacy
//! file-per-point shards fold in through the same path. All I/O goes
//! through [`StoreIo`], so `tests/chaos_store.rs` can crash and corrupt
//! every step of a merge.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::tune::plan::fnv64;
use crate::{ensure, format_err, Result};

use super::format::{encode_result_bin, parse_result};
use super::lifecycle::walk_legacy;
use super::planner::Planner;
use super::point::SimPoint;
use super::segment::{encode_record, SegmentStore, DEFAULT_ROLL_BYTES};
use super::store::ResultStore;
use super::vfs::{default_io, with_retry, StoreIo};

/// First line of a shard-ownership manifest.
pub const MANIFEST_MAGIC: &str = "MSGRID01";

/// Directory under the merge destination holding conflicting records.
pub const QUARANTINE_DIR: &str = "quarantine";

/// Which shard of `count` a key belongs to (1-based). Fixed-point range
/// partition: shard `k` owns keys in `[(k-1)/n, k/n)` of the u64 space,
/// so ownership is total, disjoint, and independent of plan order.
pub fn shard_of(key: u64, count: u32) -> u32 {
    ((key as u128 * count as u128) >> 64) as u32 + 1
}

/// One shard identity, as `--shard k/n` names it (1-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: u32,
    pub count: u32,
}

impl ShardSpec {
    pub fn new(index: u32, count: u32) -> Result<Self> {
        ensure!(count >= 1, "shard: the shard count must be at least 1");
        ensure!(
            (1..=count).contains(&index),
            "shard: index {index} out of range 1..={count}"
        );
        Ok(Self { index, count })
    }

    /// Parse the CLI form `k/n`.
    pub fn parse(s: &str) -> Result<Self> {
        let (k, n) = s
            .split_once('/')
            .ok_or_else(|| format_err!("shard: expected k/n (e.g. 1/2), got {s:?}"))?;
        let index: u32 =
            k.parse().map_err(|_| format_err!("shard: not a number: {k:?} in {s:?}"))?;
        let count: u32 =
            n.parse().map_err(|_| format_err!("shard: not a number: {n:?} in {s:?}"))?;
        Self::new(index, count)
    }

    pub fn owns(&self, key: u64) -> bool {
        shard_of(key, self.count) == self.index
    }

    pub fn label(&self) -> String {
        format!("{}/{}", self.index, self.count)
    }
}

/// Conventional manifest file name for a shard.
pub fn manifest_file_name(shard: ShardSpec) -> String {
    format!("shard-{:04}-of-{:04}.manifest", shard.index, shard.count)
}

/// A shard run's ownership record: which keys of the plan this shard
/// owned (sorted, deduped), self-checksummed against damage in transit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GridManifest {
    pub shard: ShardSpec,
    /// Total points in the plan the shard partitioned (all shards).
    pub plan_points: u64,
    /// Owned content keys, strictly increasing.
    pub keys: Vec<u64>,
}

impl GridManifest {
    pub fn serialize(&self) -> String {
        let mut body = String::new();
        body.push_str(MANIFEST_MAGIC);
        body.push('\n');
        body.push_str(&format!("shard = {}\n", self.shard.label()));
        body.push_str(&format!("plan_points = {}\n", self.plan_points));
        body.push_str(&format!("owned = {}\n", self.keys.len()));
        for k in &self.keys {
            body.push_str(&format!("key = {k:016x}\n"));
        }
        let sum = fnv64(body.as_bytes());
        format!("{body}checksum = {sum:016x}\n")
    }

    /// Strict parse: checksum, magic, field order, and key monotonicity
    /// all verified. Any damage is an error, never a partial manifest.
    pub fn parse(text: &str) -> Result<Self> {
        let at = text
            .rfind("checksum = ")
            .ok_or_else(|| format_err!("manifest: missing checksum line"))?;
        ensure!(
            at > 0 && text.as_bytes()[at - 1] == b'\n',
            "manifest: checksum must start its own line"
        );
        let (body, sum_line) = text.split_at(at);
        let sum_hex = sum_line
            .strip_prefix("checksum = ")
            .and_then(|s| s.strip_suffix('\n'))
            .ok_or_else(|| format_err!("manifest: malformed checksum line"))?;
        let sum = u64::from_str_radix(sum_hex, 16)
            .map_err(|_| format_err!("manifest: checksum is not 64-bit hex"))?;
        ensure!(
            sum == fnv64(body.as_bytes()),
            "manifest: checksum mismatch (file damaged or truncated)"
        );
        let mut lines = body.lines();
        ensure!(
            lines.next() == Some(MANIFEST_MAGIC),
            "manifest: bad magic (want {MANIFEST_MAGIC})"
        );
        let shard = lines
            .next()
            .and_then(|l| l.strip_prefix("shard = "))
            .ok_or_else(|| format_err!("manifest: missing shard field"))
            .and_then(ShardSpec::parse)?;
        let plan_points: u64 = lines
            .next()
            .and_then(|l| l.strip_prefix("plan_points = "))
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| format_err!("manifest: missing plan_points field"))?;
        let owned: u64 = lines
            .next()
            .and_then(|l| l.strip_prefix("owned = "))
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| format_err!("manifest: missing owned field"))?;
        let mut keys = Vec::new();
        for line in lines {
            let hex = line
                .strip_prefix("key = ")
                .ok_or_else(|| format_err!("manifest: unexpected line {line:?}"))?;
            let k = u64::from_str_radix(hex, 16)
                .map_err(|_| format_err!("manifest: bad key {hex:?}"))?;
            if let Some(&prev) = keys.last() {
                ensure!(k > prev, "manifest: keys must be strictly increasing");
            }
            keys.push(k);
        }
        ensure!(
            keys.len() as u64 == owned,
            "manifest: owned = {owned} but {} key lines",
            keys.len()
        );
        Ok(Self { shard, plan_points, keys })
    }
}

/// Write a manifest atomically (temp file + rename) into `dir`.
pub fn write_manifest(io: &dyn StoreIo, dir: &Path, m: &GridManifest) -> Result<PathBuf> {
    with_retry(|| io.create_dir_all(dir))
        .map_err(|e| format_err!("manifest: cannot create {dir:?}: {e}"))?;
    let name = manifest_file_name(m.shard);
    let path = dir.join(&name);
    let tmp = dir.join(format!("{name}.tmp{}", std::process::id()));
    with_retry(|| io.write(&tmp, m.serialize().as_bytes()))
        .map_err(|e| format_err!("manifest: cannot write {tmp:?}: {e}"))?;
    with_retry(|| io.rename(&tmp, &path))
        .map_err(|e| format_err!("manifest: cannot move into place at {path:?}: {e}"))?;
    Ok(path)
}

/// Load and strictly validate a manifest file.
pub fn load_manifest(io: &dyn StoreIo, path: &Path) -> Result<GridManifest> {
    let bytes =
        with_retry(|| io.read(path)).map_err(|e| format_err!("manifest {path:?}: {e}"))?;
    let text = String::from_utf8(bytes)
        .map_err(|_| format_err!("manifest {path:?}: not valid UTF-8"))?;
    GridManifest::parse(&text).map_err(|e| format_err!("manifest {path:?}: {e}"))
}

/// What `repro grid --shard k/n` did.
#[derive(Debug, Clone)]
pub struct GridReport {
    pub shard: ShardSpec,
    pub plan_points: u64,
    pub owned: u64,
    pub manifest: PathBuf,
}

/// Simulate the shard-owned subset of `points` through `store` and
/// write this shard's ownership manifest next to the segments.
pub fn run_shard(store: &ResultStore, shard: ShardSpec, points: &[SimPoint]) -> Result<GridReport> {
    let _span = crate::obs::span("grid_shard");
    let dir = store
        .dir()
        .ok_or_else(|| format_err!("grid requires a persistent result store (--results DIR)"))?
        .to_path_buf();
    let owned: Vec<SimPoint> = points.iter().filter(|p| shard.owns(p.key())).cloned().collect();
    Planner::new(store).run(&owned)?;
    store.flush();
    let mut keys: Vec<u64> = owned.iter().map(|p| p.key()).collect();
    keys.sort_unstable();
    keys.dedup();
    let manifest = GridManifest { shard, plan_points: points.len() as u64, keys };
    let owned_count = manifest.keys.len() as u64;
    let path = write_manifest(&*store.io(), &dir, &manifest)?;
    crate::obs::global().with(|v| {
        v.counter_add("grid_shards_total", 1);
        v.counter_add("grid_plan_points_total", points.len() as u64);
        v.counter_add("grid_owned_points_total", owned_count);
    });
    Ok(GridReport { shard, plan_points: points.len() as u64, owned: owned_count, manifest: path })
}

/// What a `repro store merge` did, per invariant.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MergeReport {
    /// Source directories visited.
    pub sources: u64,
    /// Records appended to the destination.
    pub merged: u64,
    /// Records already present with identical bytes (the no-op case).
    pub already_present: u64,
    /// Same-key/different-bytes records quarantined, never applied.
    pub conflicts: u64,
    /// Source or destination records dropped as corrupt along the way.
    pub corrupt_skipped: u64,
    /// … of `merged`, records folded from legacy file-per-point shards.
    pub legacy_folded: u64,
    /// Shard manifests found and validated in the sources.
    pub manifests_seen: u64,
    /// Shard manifests that failed validation (reported, not fatal).
    pub manifests_corrupt: u64,
}

impl MergeReport {
    /// Clean means no quarantined conflicts — the gate `repro store
    /// merge` exits nonzero on.
    pub fn is_clean(&self) -> bool {
        self.conflicts == 0
    }
}

enum MergeOutcome {
    Merged,
    AlreadyPresent,
    Conflict,
    /// The destination copy failed validation and was dropped; the
    /// source copy healed it.
    ReplacedCorrupt,
}

/// Union `sources` into `dest` by content key (real filesystem).
pub fn merge(sources: &[PathBuf], dest: &Path) -> Result<MergeReport> {
    merge_with(default_io(), sources, dest)
}

/// [`merge`] over an explicit I/O backend.
pub fn merge_with(io: Arc<dyn StoreIo>, sources: &[PathBuf], dest: &Path) -> Result<MergeReport> {
    let _span = crate::obs::span("store_merge");
    ensure!(!sources.is_empty(), "merge: at least one SRC directory is required");
    for s in sources {
        ensure!(
            s.as_path() != dest,
            "merge: source {} is also the destination",
            s.display()
        );
    }
    let mut dst = SegmentStore::open_with(dest, DEFAULT_ROLL_BYTES, Arc::clone(&io));
    let mut report = MergeReport { sources: sources.len() as u64, ..MergeReport::default() };
    for src_dir in sources {
        let tag = source_tag(src_dir);
        // Manifests ride along for audit; a corrupt one is reported but
        // does not block the byte-level union below.
        if let Ok(entries) = io.list_dir(src_dir) {
            for e in entries {
                let p = src_dir.join(&e.name);
                if e.is_dir || p.extension().and_then(|x| x.to_str()) != Some("manifest") {
                    continue;
                }
                match load_manifest(&*io, &p) {
                    Ok(_) => report.manifests_seen += 1,
                    Err(err) => {
                        report.manifests_corrupt += 1;
                        eprintln!("[merge] corrupt manifest {}: {err}", p.display());
                    }
                }
            }
        }
        let mut src = SegmentStore::open_with(src_dir, DEFAULT_ROLL_BYTES, Arc::clone(&io));
        let mut keys: Vec<u64> = src.entries().into_iter().map(|(k, _)| k).collect();
        keys.sort_unstable();
        for key in keys {
            match src.read_raw(key) {
                None => {}
                Some(Err(e)) => {
                    report.corrupt_skipped += 1;
                    eprintln!("[merge] corrupt source record {key:#018x} skipped: {e}");
                }
                Some(Ok((stamp, payload))) => {
                    match merge_one(&*io, &mut dst, dest, &tag, key, stamp, &payload)? {
                        MergeOutcome::Merged => report.merged += 1,
                        MergeOutcome::AlreadyPresent => report.already_present += 1,
                        MergeOutcome::Conflict => report.conflicts += 1,
                        MergeOutcome::ReplacedCorrupt => {
                            report.merged += 1;
                            report.corrupt_skipped += 1;
                        }
                    }
                }
            }
        }
        // Legacy file-per-point shards fold in through the same path.
        let mut failed = None;
        walk_legacy(&*io, src_dir, |p, e| {
            if failed.is_some() {
                return;
            }
            let parsed = io
                .read(p)
                .ok()
                .and_then(|b| String::from_utf8(b).ok())
                .and_then(|t| parse_result(&t).ok());
            let Some((key, result)) = parsed else {
                report.corrupt_skipped += 1;
                eprintln!("[merge] corrupt legacy shard {} skipped", p.display());
                return;
            };
            let payload = encode_result_bin(&result);
            match merge_one(&*io, &mut dst, dest, &tag, key, e.mtime_secs, &payload) {
                Ok(MergeOutcome::Merged) => {
                    report.merged += 1;
                    report.legacy_folded += 1;
                }
                Ok(MergeOutcome::AlreadyPresent) => report.already_present += 1,
                Ok(MergeOutcome::Conflict) => report.conflicts += 1,
                Ok(MergeOutcome::ReplacedCorrupt) => {
                    report.merged += 1;
                    report.legacy_folded += 1;
                    report.corrupt_skipped += 1;
                }
                Err(err) => failed = Some(err),
            }
        });
        if let Some(e) = failed {
            return Err(e);
        }
    }
    dst.flush_index()?;
    crate::obs::global().with(|v| {
        v.counter_add("grid_merges_total", 1);
        v.counter_add("grid_merge_sources_total", report.sources);
        v.counter_add("grid_merged_records_total", report.merged);
        v.counter_add("grid_merge_already_present_total", report.already_present);
        v.counter_add("grid_merge_conflicts_total", report.conflicts);
        v.counter_add("grid_merge_corrupt_skipped_total", report.corrupt_skipped);
    });
    Ok(report)
}

/// Merge one record into the destination: append when absent, no-op on
/// identical bytes, quarantine on divergent bytes (the destination copy
/// always survives), heal when the destination copy itself is corrupt.
fn merge_one(
    io: &dyn StoreIo,
    dst: &mut SegmentStore,
    dest: &Path,
    src_tag: &str,
    key: u64,
    stamp: u64,
    payload: &[u8],
) -> Result<MergeOutcome> {
    match dst.read_raw(key) {
        None => {
            dst.append_payload(key, stamp, payload)?;
            Ok(MergeOutcome::Merged)
        }
        Some(Ok((_stamp, existing))) if existing == payload => Ok(MergeOutcome::AlreadyPresent),
        Some(Ok(_)) => {
            quarantine(io, dest, src_tag, key, stamp, payload);
            Ok(MergeOutcome::Conflict)
        }
        Some(Err(e)) => {
            eprintln!("[merge] dest record {key:#018x} was corrupt ({e}); healed from source");
            dst.append_payload(key, stamp, payload)?;
            Ok(MergeOutcome::ReplacedCorrupt)
        }
    }
}

/// Park a conflicting source record under `<dest>/quarantine/` as a
/// full checksummed record frame. Best-effort: the conflict is counted
/// either way, and the source bytes are never applied.
fn quarantine(io: &dyn StoreIo, dest: &Path, src_tag: &str, key: u64, stamp: u64, payload: &[u8]) {
    let dir = dest.join(QUARANTINE_DIR);
    let path = dir.join(format!("{key:016x}-{src_tag}.rec"));
    let frame = encode_record(key, stamp, payload);
    let wrote = with_retry(|| io.create_dir_all(&dir))
        .and_then(|()| with_retry(|| io.write(&path, &frame)));
    match wrote {
        Ok(()) => eprintln!(
            "[merge] CONFLICT: key {key:#018x} differs between source and destination; \
             source bytes quarantined at {} (never silently chosen)",
            path.display()
        ),
        Err(e) => eprintln!(
            "[merge] CONFLICT: key {key:#018x} differs between source and destination; \
             quarantine write failed ({e}) — source bytes NOT applied"
        ),
    }
}

/// A filesystem-safe tag naming a source directory in quarantine files.
fn source_tag(dir: &Path) -> String {
    let name = dir.file_name().and_then(|n| n.to_str()).unwrap_or("src");
    let tag: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    if tag.is_empty() {
        "src".to_string()
    } else {
        tag
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_key(i: u64) -> u64 {
        (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    #[test]
    fn partition_is_total_and_disjoint() {
        for n in [1u32, 2, 3, 7, 16] {
            for i in 0..500u64 {
                let key = synth_key(i);
                let owner = shard_of(key, n);
                assert!((1..=n).contains(&owner), "owner {owner} of {n} for {key:#x}");
                let owners = (1..=n)
                    .filter(|&k| ShardSpec::new(k, n).unwrap().owns(key))
                    .count();
                assert_eq!(owners, 1, "key {key:#x} must have exactly one owner of {n}");
            }
        }
        assert_eq!(shard_of(0, 8), 1, "the low edge lands in the first shard");
        assert_eq!(shard_of(u64::MAX, 8), 8, "the high edge lands in the last shard");
    }

    #[test]
    fn shard_spec_parses_and_rejects() {
        assert_eq!(ShardSpec::parse("2/4").unwrap(), ShardSpec { index: 2, count: 4 });
        for bad in ["0/2", "3/2", "2", "a/b", "", "1/0", "1/2/3"] {
            assert!(ShardSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn manifest_roundtrips_and_detects_tampering() {
        let m = GridManifest {
            shard: ShardSpec::new(2, 3).unwrap(),
            plan_points: 100,
            keys: vec![1, 5, 0xdead_beef],
        };
        let text = m.serialize();
        assert_eq!(GridManifest::parse(&text).unwrap(), m);
        let tampered = text.replace("key = 0000000000000005", "key = 0000000000000006");
        assert_ne!(tampered, text, "the tamper target line must exist");
        assert!(GridManifest::parse(&tampered).is_err(), "checksum catches a flipped key");
        assert!(GridManifest::parse(&text[..text.len() - 3]).is_err(), "truncation caught");
    }

    #[test]
    fn merge_unions_disjoint_dirs_and_is_idempotent() {
        let base = std::env::temp_dir().join(format!("msgrid_merge_{}", std::process::id()));
        std::fs::remove_dir_all(&base).ok();
        let (a, b, dst) = (base.join("a"), base.join("b"), base.join("dst"));
        {
            let io = default_io();
            let mut sa = SegmentStore::open_with(&a, DEFAULT_ROLL_BYTES, Arc::clone(&io));
            let mut sb = SegmentStore::open_with(&b, DEFAULT_ROLL_BYTES, io);
            for i in 0..10u64 {
                let key = synth_key(i);
                let store = if shard_of(key, 2) == 1 { &mut sa } else { &mut sb };
                store.append_payload(key, 7, format!("payload-{i}").as_bytes()).unwrap();
            }
            sa.flush_index().unwrap();
            sb.flush_index().unwrap();
        }
        let r = merge(&[a.clone(), b.clone()], &dst).unwrap();
        assert_eq!(r.merged, 10);
        assert_eq!(r.conflicts, 0);
        assert!(r.is_clean());
        let again = merge(&[a, b], &dst).unwrap();
        assert_eq!(again.merged, 0, "re-merge is a no-op");
        assert_eq!(again.already_present, 10);
        let mut d = SegmentStore::open(&dst, DEFAULT_ROLL_BYTES);
        for i in 0..10u64 {
            let (stamp, payload) = d.read_raw(synth_key(i)).unwrap().unwrap();
            assert_eq!(stamp, 7);
            assert_eq!(payload, format!("payload-{i}").into_bytes());
        }
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn merge_refuses_a_source_equal_to_the_destination() {
        let d = PathBuf::from("/tmp/msgrid_same");
        assert!(merge(&[d.clone()], &d).is_err());
        assert!(merge(&[], Path::new("/tmp/msgrid_empty")).is_err());
    }
}
