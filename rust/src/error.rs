//! Crate-wide error type.
//!
//! No third-party crates are available offline, so instead of `anyhow`
//! the crate ships this minimal equivalent: an opaque [`Error`] holding a
//! message plus an optional source, a blanket `From` for any standard
//! error (so `?` works on `io::Error` and friends), and the
//! [`format_err!`] / [`bail!`] / [`ensure!`] macros.

use std::fmt;

/// An opaque error: a message plus an optional underlying cause.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string(), source: None }
    }

    /// The underlying cause, if one was recorded.
    pub fn source(&self) -> Option<&(dyn std::error::Error + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(s) = &self.source {
            write!(f, "\n\ncaused by: {s}")?;
        }
        Ok(())
    }
}

// Like `anyhow::Error`, `Error` intentionally does NOT implement
// `std::error::Error`; that is what makes this blanket conversion legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Attach context to an `Option` or `Result`, producing a `crate::Result`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T, Error>;
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(msg))
    }
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{msg}: {e}"), source: Some(Box::new(e)) })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{bail, ensure};

    fn io_fail() -> crate::Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/real/path/xyz")?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.source().is_some());
    }

    #[test]
    fn macros_build_messages() {
        let f = || -> crate::Result<()> {
            ensure!(1 + 1 == 2, "math broke");
            bail!("reached {} as planned", "bail");
        };
        assert_eq!(f().unwrap_err().to_string(), "reached bail as planned");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing value").unwrap_err().to_string(), "missing value");
    }
}
