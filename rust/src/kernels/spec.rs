//! Affine loop-nest IR.
//!
//! The multi-striding methodology of §5.1 operates on kernels that are
//! "free of (loop-carried) data dependencies that enforce a fixed order of
//! execution". This IR captures exactly what the transformation needs:
//!
//! * a perfect loop nest of [`LoopVar`]s (outermost first);
//! * row-major [`Array`]s laid out in a single simulated address space;
//! * [`ArrayAccess`]es whose every subscript is an [`IndexExpr`] — an
//!   affine function of the loop variables.

/// Read/write mode of an access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessMode {
    Read,
    Write,
    /// Read-modify-write of the same address (e.g. `C[i] += …`).
    ReadWrite,
}

/// One loop of the nest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopVar {
    /// Human name (`"i"`, `"j"`, …).
    pub name: String,
    /// Trip count.
    pub extent: u64,
}

impl LoopVar {
    pub fn new(name: &str, extent: u64) -> Self {
        Self { name: name.to_string(), extent }
    }
}

/// A dense row-major array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Array {
    pub name: String,
    /// Dimension sizes, outermost first.
    pub dims: Vec<u64>,
    /// Element size in bytes (4 for the paper's single-precision floats).
    pub elem_bytes: u32,
    /// Base byte address within the simulated address space. Assigned by
    /// [`KernelSpec::layout`].
    pub base: u64,
}

impl Array {
    pub fn new(name: &str, dims: &[u64], elem_bytes: u32) -> Self {
        Self { name: name.to_string(), dims: dims.to_vec(), elem_bytes, base: 0 }
    }

    /// Total size in bytes.
    pub fn bytes(&self) -> u64 {
        self.dims.iter().product::<u64>() * self.elem_bytes as u64
    }

    /// Row-major linear stride (in elements) of dimension `d`.
    pub fn dim_stride(&self, d: usize) -> u64 {
        self.dims[d + 1..].iter().product()
    }
}

/// An affine subscript: `Σ coef·loop_var + offset`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct IndexExpr {
    /// `(loop index, coefficient)` terms; loop index refers to
    /// [`KernelSpec::loops`] order.
    pub terms: Vec<(usize, i64)>,
    pub offset: i64,
}

impl IndexExpr {
    /// The subscript `var` (coefficient 1, offset 0).
    pub fn var(loop_idx: usize) -> Self {
        Self { terms: vec![(loop_idx, 1)], offset: 0 }
    }

    /// The subscript `var + offset` (stencils).
    pub fn var_plus(loop_idx: usize, offset: i64) -> Self {
        Self { terms: vec![(loop_idx, 1)], offset }
    }

    /// A constant subscript.
    pub fn constant(offset: i64) -> Self {
        Self { terms: vec![], offset }
    }

    /// Evaluate at concrete loop values.
    pub fn eval(&self, loop_vals: &[u64]) -> i64 {
        self.terms.iter().map(|&(l, c)| c * loop_vals[l] as i64).sum::<i64>() + self.offset
    }

    /// Does the expression reference loop `l`?
    pub fn uses(&self, l: usize) -> bool {
        self.terms.iter().any(|&(t, c)| t == l && c != 0)
    }

    /// Coefficient of loop `l` (0 when absent).
    pub fn coef(&self, l: usize) -> i64 {
        self.terms.iter().find(|&&(t, _)| t == l).map_or(0, |&(_, c)| c)
    }
}

/// One array access in the innermost body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayAccess {
    /// Index into [`KernelSpec::arrays`].
    pub array: usize,
    /// One subscript per array dimension.
    pub idx: Vec<IndexExpr>,
    pub mode: AccessMode,
}

impl ArrayAccess {
    pub fn new(array: usize, idx: Vec<IndexExpr>, mode: AccessMode) -> Self {
        Self { array, idx, mode }
    }

    /// Deepest loop (by spec order) this access depends on, if any.
    pub fn deepest_loop(&self, n_loops: usize) -> Option<usize> {
        (0..n_loops).rev().find(|&l| self.idx.iter().any(|e| e.uses(l)))
    }

    /// Byte offset of the accessed element within the array, at concrete
    /// loop values. `None` if any subscript is negative (stencil border —
    /// the library pads extents so this cannot happen in-bounds).
    pub fn elem_offset(&self, arr: &Array, loop_vals: &[u64]) -> Option<u64> {
        let mut linear: i64 = 0;
        for (d, e) in self.idx.iter().enumerate() {
            let v = e.eval(loop_vals);
            if v < 0 || v as u64 >= arr.dims[d] {
                return None;
            }
            linear += v * arr.dim_stride(d) as i64;
        }
        Some(linear as u64 * arr.elem_bytes as u64)
    }
}

/// A complete kernel: loop nest + arrays + body accesses.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    pub name: String,
    /// Loop nest, outermost first (the *source* order; the transform may
    /// interchange).
    pub loops: Vec<LoopVar>,
    pub arrays: Vec<Array>,
    pub accesses: Vec<ArrayAccess>,
    /// Kernel carries a dependence that forbids reordering (multi-striding
    /// is then inapplicable; §5.1).
    pub loop_carried_dep: bool,
}

impl KernelSpec {
    /// Assign array base addresses: arrays are laid out back-to-back,
    /// each aligned to a 4 KiB page (as `aligned_alloc` would).
    pub fn layout(&mut self) {
        let mut base = 0u64;
        for a in &mut self.arrays {
            a.base = base;
            let sz = a.bytes();
            base += sz.div_ceil(4096) * 4096;
            // Guard page between arrays so streams never coalesce.
            base += 4096;
        }
    }

    /// Total data footprint in bytes.
    pub fn footprint(&self) -> u64 {
        self.arrays.iter().map(|a| a.bytes()).sum()
    }

    /// Absolute byte address of an access at concrete loop values.
    pub fn address(&self, acc: &ArrayAccess, loop_vals: &[u64]) -> Option<u64> {
        let arr = &self.arrays[acc.array];
        acc.elem_offset(arr, loop_vals).map(|o| arr.base + o)
    }

    /// Find the loop index by name (panics if absent — library invariant).
    pub fn loop_named(&self, name: &str) -> usize {
        self.loops
            .iter()
            .position(|l| l.name == name)
            .unwrap_or_else(|| panic!("no loop named {name} in {}", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// C[i] += A[i][j] * B[j] — plain matrix-vector product.
    fn mxv(n: u64, m: u64) -> KernelSpec {
        let mut k = KernelSpec {
            name: "mxv".into(),
            loops: vec![LoopVar::new("i", n), LoopVar::new("j", m)],
            arrays: vec![
                Array::new("A", &[n, m], 4),
                Array::new("B", &[m], 4),
                Array::new("C", &[n], 4),
            ],
            accesses: vec![
                ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
                ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
                ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
            ],
            loop_carried_dep: false,
        };
        k.layout();
        k
    }

    #[test]
    fn layout_is_page_aligned_and_disjoint() {
        let k = mxv(64, 64);
        for a in &k.arrays {
            assert_eq!(a.base % 4096, 0);
        }
        for w in k.arrays.windows(2) {
            assert!(w[0].base + w[0].bytes() < w[1].base);
        }
    }

    #[test]
    fn address_evaluation_row_major() {
        let k = mxv(8, 16);
        let a = &k.accesses[0];
        // A[2][3] = base + (2*16+3)*4
        let addr = k.address(a, &[2, 3]).unwrap();
        assert_eq!(addr, k.arrays[0].base + 35 * 4);
    }

    #[test]
    fn index_expr_eval() {
        let e = IndexExpr::var_plus(1, -1);
        assert_eq!(e.eval(&[0, 5]), 4);
        assert!(e.uses(1));
        assert!(!e.uses(0));
        assert_eq!(e.coef(1), 1);
        let c = IndexExpr::constant(7);
        assert_eq!(c.eval(&[1, 2]), 7);
    }

    #[test]
    fn out_of_bounds_returns_none() {
        let k = mxv(8, 16);
        let a = &k.accesses[0];
        assert!(k.address(a, &[8, 0]).is_none());
        // Negative subscript via stencil-style offset:
        let st = ArrayAccess::new(
            0,
            vec![IndexExpr::var_plus(0, -1), IndexExpr::var(1)],
            AccessMode::Read,
        );
        assert!(k.address(&st, &[0, 0]).is_none());
    }

    #[test]
    fn deepest_loop_detection() {
        let k = mxv(8, 16);
        assert_eq!(k.accesses[0].deepest_loop(2), Some(1)); // A[i][j] -> j
        assert_eq!(k.accesses[1].deepest_loop(2), Some(1)); // B[j] -> j
        assert_eq!(k.accesses[2].deepest_loop(2), Some(0)); // C[i] -> i
    }

    #[test]
    fn footprint_accounting() {
        let k = mxv(8, 16);
        assert_eq!(k.footprint(), (8 * 16 + 16 + 8) * 4);
    }
}
