//! The §4 micro-benchmarks behind Figures 2–5.
//!
//! Each benchmark is "a single loop that processes the data stored in an
//! array using solely data-movement instructions" with a **constant budget
//! of 32 unroll slots** evenly distributed over the configured number of
//! stride unrolls (§4.1). The only differences between configurations are
//! the access offsets and the base-register step — exactly the isolation
//! argument the paper makes.

use crate::trace::{Access, Arrangement, Op};

/// Which data-movement instruction mix a micro-benchmark runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MicroOp {
    /// `vmovaps` loads.
    LoadAligned,
    /// `vmovups` loads at a +4 B offset.
    LoadUnaligned,
    /// `vmovntdqa` loads.
    LoadNt,
    /// `vmovaps` stores.
    StoreAligned,
    /// `vmovups` stores at a +4 B offset.
    StoreUnaligned,
    /// `vmovntdq` stores.
    StoreNt,
    /// Copy: aligned loads + aligned stores.
    CopyAligned,
    /// Copy: aligned loads + non-temporal stores.
    CopyNt,
    /// Copy: non-temporal loads + non-temporal stores.
    CopyNtBoth,
}

impl MicroOp {
    pub fn all() -> [MicroOp; 9] {
        [
            Self::LoadAligned,
            Self::LoadUnaligned,
            Self::LoadNt,
            Self::StoreAligned,
            Self::StoreUnaligned,
            Self::StoreNt,
            Self::CopyAligned,
            Self::CopyNt,
            Self::CopyNtBoth,
        ]
    }

    /// (load op, store op) pair this mix issues.
    fn ops(self) -> (Option<Op>, Option<Op>) {
        match self {
            Self::LoadAligned => (Some(Op::Load), None),
            Self::LoadUnaligned => (Some(Op::LoadU), None),
            Self::LoadNt => (Some(Op::LoadNt), None),
            Self::StoreAligned => (None, Some(Op::Store)),
            Self::StoreUnaligned => (None, Some(Op::StoreU)),
            Self::StoreNt => (None, Some(Op::StoreNt)),
            Self::CopyAligned => (Some(Op::Load), Some(Op::Store)),
            Self::CopyNt => (Some(Op::Load), Some(Op::StoreNt)),
            Self::CopyNtBoth => (Some(Op::LoadNt), Some(Op::StoreNt)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::LoadAligned => "aligned loads",
            Self::LoadUnaligned => "unaligned loads",
            Self::LoadNt => "non-temporal loads",
            Self::StoreAligned => "aligned stores",
            Self::StoreUnaligned => "unaligned stores",
            Self::StoreNt => "non-temporal stores",
            Self::CopyAligned => "copy (aligned stores)",
            Self::CopyNt => "copy (NT stores)",
            Self::CopyNtBoth => "copy (NT loads+stores)",
        }
    }
}

/// One micro-benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct MicroBench {
    pub op: MicroOp,
    /// Number of concurrent strides (1, 2, 4, 8, 16, 32 in the paper).
    pub strides: u32,
    /// Total bytes of array data processed per kernel execution.
    pub array_bytes: u64,
    /// Grouped (default) or interleaved body arrangement.
    pub arrangement: Arrangement,
}

/// The fixed unroll-slot budget of §4.1.
pub const UNROLL_SLOTS: u32 = 32;

impl MicroBench {
    pub fn new(op: MicroOp, strides: u32, array_bytes: u64) -> Self {
        assert!(strides >= 1 && UNROLL_SLOTS % strides == 0, "strides must divide 32");
        Self { op, strides, array_bytes, arrangement: Arrangement::Grouped }
    }

    pub fn interleaved(mut self) -> Self {
        self.arrangement = Arrangement::Interleaved;
        self
    }

    /// Is this a copy benchmark (separate source and destination regions)?
    pub fn is_copy(&self) -> bool {
        matches!(self.op, MicroOp::CopyAligned | MicroOp::CopyNt | MicroOp::CopyNtBoth)
    }

    /// Number of vector accesses the trace will contain.
    pub fn trace_len(&self) -> u64 {
        // Every 32 data bytes is touched by one vector op per involved
        // direction; copies touch src+dst halves once each.
        self.array_bytes / 32
    }

    /// Generate the access trace lazily.
    ///
    /// Layout: the array is split into `strides` equal contiguous regions;
    /// stride *k* walks region *k*. With `strides == 1` this degenerates to
    /// the single-strided 32-unrolled baseline of §4.2.
    pub fn trace(&self) -> impl Iterator<Item = Access> + '_ {
        let n = self.strides as u64;
        let (load_op, store_op) = self.op.ops();
        let is_copy = self.is_copy();

        // For copies, the data region is split into source and destination
        // halves; each stride then owns a region in both halves.
        let data = self.array_bytes;
        let (src_base, dst_base, region_total) =
            if is_copy { (0u64, data / 2, data / 2) } else { (0u64, 0u64, data) };
        let stride_span = region_total / n;
        let vectors_per_stride = stride_span / 32;
        let portion = (UNROLL_SLOTS as u64 / n).max(1);
        let iterations = vectors_per_stride / portion;
        let arrangement = self.arrangement;

        // Iteration state: (iteration, slot) flattened.
        let total_slots_per_iter = n * portion;
        let mut iter_idx = 0u64;
        let mut slot_idx = 0u64;

        std::iter::from_fn(move || {
            loop {
                if iter_idx >= iterations {
                    return None;
                }
                if slot_idx >= total_slots_per_iter * if is_copy { 2 } else { 1 } {
                    slot_idx = 0;
                    iter_idx += 1;
                    continue;
                }
                // For copies, even sub-slots are the load, odd the store
                // (load a; store b — per vector, like STREAM copy).
                let (pair, op) = if is_copy {
                    let pair = slot_idx / 2;
                    let op = if slot_idx % 2 == 0 { load_op.unwrap() } else { store_op.unwrap() };
                    (pair, op)
                } else {
                    (slot_idx, load_op.or(store_op).unwrap())
                };

                // Map the flattened slot to (stride, portion offset).
                let (s, u) = match arrangement {
                    Arrangement::Grouped => (pair / portion, pair % portion),
                    Arrangement::Interleaved => (pair % n, pair / n),
                };

                let base = if op.is_store() && is_copy { dst_base } else { src_base };
                let addr = base
                    + s * stride_span
                    + (iter_idx * portion + u) * 32
                    + op.addr_offset();
                slot_idx += 1;
                return Some(Access::new(addr, op, 32, pair as u32));
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    const MIB: u64 = 1 << 20;

    #[test]
    fn trace_covers_every_vector_exactly_once_loads() {
        for strides in [1u32, 2, 4, 8, 16, 32] {
            let b = MicroBench::new(MicroOp::LoadAligned, strides, MIB);
            let addrs: HashSet<u64> = b.trace().map(|a| a.addr).collect();
            assert_eq!(addrs.len() as u64, MIB / 32, "strides={strides}");
            assert_eq!(b.trace().count() as u64, b.trace_len());
        }
    }

    #[test]
    fn copy_touches_src_and_dst_halves() {
        let b = MicroBench::new(MicroOp::CopyAligned, 4, 2 * MIB);
        let mut reads = 0u64;
        let mut writes = 0u64;
        for a in b.trace() {
            if a.op.is_store() {
                assert!(a.addr >= MIB, "stores in dst half");
                writes += 1;
            } else {
                assert!(a.addr < MIB, "loads in src half");
                reads += 1;
            }
        }
        assert_eq!(reads, MIB / 32);
        assert_eq!(writes, MIB / 32);
    }

    #[test]
    fn grouped_vs_interleaved_ordering() {
        let g = MicroBench::new(MicroOp::StoreNt, 4, MIB);
        let i = MicroBench::new(MicroOp::StoreNt, 4, MIB).interleaved();
        let first_g: Vec<u64> = g.trace().take(8).map(|a| a.addr).collect();
        let first_i: Vec<u64> = i.trace().take(8).map(|a| a.addr).collect();
        let span = MIB / 4;
        // Grouped: all 8 slots of stride 0 first (consecutive 32 B steps).
        assert!(first_g.windows(2).all(|w| w[1] == w[0] + 32));
        // Interleaved: consecutive slots hop between strides.
        assert_eq!(first_i[1] - first_i[0], span);
    }

    #[test]
    fn unaligned_offsets_applied() {
        let b = MicroBench::new(MicroOp::LoadUnaligned, 1, MIB);
        assert!(b.trace().all(|a| a.addr % 32 == 4));
    }

    #[test]
    fn single_stride_is_sequential() {
        let b = MicroBench::new(MicroOp::LoadAligned, 1, MIB);
        let addrs: Vec<u64> = b.trace().take(100).map(|a| a.addr).collect();
        assert!(addrs.windows(2).all(|w| w[1] == w[0] + 32));
    }

    #[test]
    fn ip_stable_across_iterations() {
        let b = MicroBench::new(MicroOp::LoadAligned, 4, MIB);
        let per_iter = 32usize;
        let trace: Vec<Access> = b.trace().take(per_iter * 3).collect();
        for k in 0..per_iter {
            assert_eq!(trace[k].ip, trace[k + per_iter].ip);
            assert_eq!(trace[k].ip, trace[k + 2 * per_iter].ip);
        }
    }

    #[test]
    #[should_panic(expected = "strides must divide 32")]
    fn invalid_stride_count_rejected() {
        MicroBench::new(MicroOp::LoadAligned, 3, MIB);
    }
}
