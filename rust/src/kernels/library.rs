//! The surveyed compute kernels of Table 1, expressed in the loop-nest IR.
//!
//! Sizing: each constructor takes a byte budget for the kernel's dominant
//! array (the paper uses 2–4 GiB; the default simulator scale is 48 MiB —
//! see [`crate::config::ScaleConfig`] for why that preserves behaviour).
//! Matrix extents are rounded to multiples of 1024 so every striding
//! configuration the experiments sweep divides them cleanly.

use super::spec::{AccessMode, Array, ArrayAccess, IndexExpr, KernelSpec, LoopVar};

/// Metadata mirroring the descriptive columns of Table 1.
#[derive(Debug, Clone)]
pub struct PaperKernel {
    pub name: String,
    pub description: &'static str,
    /// `true` → aligned AVX2 ops (`A` in the table); `false` → unaligned
    /// (`U`; the two stencils, because padding breaks 32-byte alignment).
    pub aligned: bool,
    /// Has an initialization phase (IN column).
    pub has_init: bool,
    /// Has a write-back phase (WB column).
    pub has_writeback: bool,
    /// Loop embedment: number of enclosing outer loops removed for the
    /// isolated experiments (LE column).
    pub loop_embedment: u32,
    /// Loop interchange applied during transformation (LI column).
    pub loop_interchange: bool,
    /// Loop blocking applied (LB column).
    pub loop_blocking: bool,
    /// Paper's data sizes in GiB (isolated, comparison) — for Table 1.
    pub data_gib: (f64, f64),
    /// The kernel body.
    pub spec: KernelSpec,
}

/// Square matrix extent for a byte budget, rounded down to a multiple of
/// 1024 (so 1..=32-way striding configs divide it).
fn square_extent(budget_bytes: u64) -> u64 {
    let n = ((budget_bytes / 4) as f64).sqrt() as u64;
    (n / 1024).max(1) * 1024
}

/// 1-D extent for a byte budget, multiple of 1024·64 elements.
fn vec_extent(budget_bytes: u64) -> u64 {
    let n = budget_bytes / 4;
    (n / (1024 * 64)).max(1) * 1024 * 64
}

fn finished(mut spec: KernelSpec) -> KernelSpec {
    spec.layout();
    spec
}

/// `mxv`: y[i] += A[i][j] · x[j] — dense matrix-vector multiplication.
pub fn mxv(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "mxv".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("x", &[n], 4),
            Array::new("y", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "mxv".into(),
        description: "Matrix Vector Multiplication",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        spec,
    }
}

/// `bicg`: s[j] += r[i]·A[i][j]; q[i] += A[i][j]·p[j] — the BiCG sub-kernel.
/// `q` accumulates in a register across the row and stores once (the init
/// phase zeroes it), hence its Table 1 classification as a store stream.
pub fn bicg(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "bicg".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("p", &[n], 4),
            Array::new("r", &[n], 4),
            Array::new("s", &[n], 4),
            Array::new("q", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(3, vec![IndexExpr::var(1)], AccessMode::ReadWrite),
            ArrayAccess::new(4, vec![IndexExpr::var(0)], AccessMode::Write),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "bicg".into(),
        description: "BiCG Sub Kernel of BiCGStab Linear Solver",
        aligned: true,
        has_init: true,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        spec,
    }
}

/// `conv`: 3×3 2-D convolution stencil (valid mode, interior loops so every
/// subscript is non-negative). Unaligned: the ±1-element offsets of the
/// window break 32-byte alignment.
pub fn conv(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let (h, w) = (n, n);
    let mut accesses = Vec::new();
    for di in 0..3i64 {
        for dj in 0..3i64 {
            accesses.push(ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, di), IndexExpr::var_plus(1, dj)],
                AccessMode::Read,
            ));
        }
    }
    accesses.push(ArrayAccess::new(
        1,
        vec![IndexExpr::var(0), IndexExpr::var(1)],
        AccessMode::Write,
    ));
    // Interior extents rounded to sweep-divisible multiples of 64.
    let (ih, iw) = (((h - 2) / 64) * 64, ((w - 2) / 64) * 64);
    let spec = finished(KernelSpec {
        name: "conv".into(),
        loops: vec![LoopVar::new("i", ih), LoopVar::new("j", iw)],
        arrays: vec![Array::new("in", &[h, w], 4), Array::new("out", &[h - 2, w - 2], 4)],
        accesses,
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "conv".into(),
        description: "3x3 2D Convolution Stencil",
        aligned: false,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (2.0, 2.0),
        spec,
    }
}

/// `doitgen` (isolated per §6.1: the two unnecessary outer loops `r, q`
/// removed, init/write-back split off): sum[p] += A[s] · C4[s][p] — after
/// the paper's loop interchange this is the transposed-MxV shape.
pub fn doitgen(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "doitgen".into(),
        loops: vec![LoopVar::new("s", n), LoopVar::new("p", n)],
        arrays: vec![
            Array::new("C4", &[n, n], 4),
            Array::new("A", &[n], 4),
            Array::new("sum", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(1)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "doitgen".into(),
        description: "Multi-resolution analysis kernel (MADNESS)",
        aligned: true,
        has_init: true,
        has_writeback: true,
        loop_embedment: 2,
        loop_interchange: true,
        loop_blocking: false,
        data_gib: (4.0, 0.4),
        spec,
    }
}

/// `gemverouter`: A[i][j] += u1[i]·v1[j] + u2[i]·v2[j] — double rank-1
/// update.
pub fn gemverouter(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "gemverouter".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("u1", &[n], 4),
            Array::new("v1", &[n], 4),
            Array::new("u2", &[n], 4),
            Array::new("v2", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::ReadWrite),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(3, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(4, vec![IndexExpr::var(1)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "gemverouter".into(),
        description: "Double Rank-1 Matrix Update",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        spec,
    }
}

/// `gemvermxv1`: x[i] += β·A[j][i]·y[j] — *transposed* matrix-vector
/// multiplication (the paper's Listing 1; requires loop interchange).
pub fn gemvermxv1(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "gemvermxv1".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("y", &[n], 4),
            Array::new("x", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(1), IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "gemvermxv1".into(),
        description: "Transpose Matrix Vector Multiplication",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: true,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        spec,
    }
}

/// `gemversum`: x[i] = x[i] + z[i] — vector sum update (1-D; needs loop
/// blocking to create strides). The x stream reads and writes the same
/// positions; Table 1 lists it under separate L and S columns, our profiler
/// reports it as a combined L/S stream (same information).
pub fn gemversum(budget: u64) -> PaperKernel {
    let n = vec_extent(budget / 2);
    let spec = finished(KernelSpec {
        name: "gemversum".into(),
        loops: vec![LoopVar::new("i", n)],
        arrays: vec![Array::new("x", &[n], 4), Array::new("z", &[n], 4)],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "gemversum".into(),
        description: "Vector Sum Update",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (4.0, 4.0),
        spec,
    }
}

/// `gemvermxv2`: w[i] += α·A[i][j]·x[j] — plain matrix-vector
/// multiplication (same shape as `mxv`).
pub fn gemvermxv2(budget: u64) -> PaperKernel {
    let mut k = mxv(budget);
    k.name = "gemvermxv2".into();
    k.spec.name = "gemvermxv2".into();
    k.description = "Matrix Vector Multiplication";
    k
}

/// `jacobi2d`: B[i+1][j+1] = 0.2·(A[i+1][j+1] + A[i+1][j] + A[i+1][j+2] +
/// A[i][j+1] + A[i+2][j+1]) — 5-point stencil over the interior.
pub fn jacobi2d(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let (h, w) = (n, n);
    let (ih, iw) = (((h - 2) / 64) * 64, ((w - 2) / 64) * 64);
    let spec = finished(KernelSpec {
        name: "jacobi2d".into(),
        loops: vec![LoopVar::new("i", ih), LoopVar::new("j", iw)],
        arrays: vec![Array::new("A", &[h, w], 4), Array::new("B", &[h, w], 4)],
        accesses: vec![
            // Center + four neighbours (all offsets non-negative: interior).
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 1)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 0)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 2)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 0), IndexExpr::var_plus(1, 1)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 2), IndexExpr::var_plus(1, 1)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                1,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 1)],
                AccessMode::Write,
            ),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "jacobi2d".into(),
        description: "2D Jacobi Stencil",
        aligned: false,
        has_init: false,
        has_writeback: true,
        loop_embedment: 1,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (2.0, 2.0),
        spec,
    }
}

/// `init`: A[i] = 0 — the initialization phase kernel (1-D, loop blocked).
pub fn init(budget: u64) -> PaperKernel {
    let n = vec_extent(budget);
    let spec = finished(KernelSpec {
        name: "init".into(),
        loops: vec![LoopVar::new("i", n)],
        arrays: vec![Array::new("A", &[n], 4)],
        accesses: vec![ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Write)],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "init".into(),
        description: "Initialization",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (2.0, 2.0),
        spec,
    }
}

/// `writeback`: A[i] = B[i] — the write-back phase kernel (1-D copy).
pub fn writeback(budget: u64) -> PaperKernel {
    let n = vec_extent(budget / 2);
    let spec = finished(KernelSpec {
        name: "writeback".into(),
        loops: vec![LoopVar::new("i", n)],
        arrays: vec![Array::new("A", &[n], 4), Array::new("B", &[n], 4)],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Write),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "writeback".into(),
        description: "Writeback",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (2.0, 2.0),
        spec,
    }
}

/// All Table 1 kernels (six surveyed kernels with gemver's four parts,
/// plus the init/writeback phase kernels), dominant array sized to
/// `budget` bytes.
pub fn paper_kernels(budget: u64) -> Vec<PaperKernel> {
    vec![
        bicg(budget),
        conv(budget),
        doitgen(budget),
        gemverouter(budget),
        gemvermxv1(budget),
        gemversum(budget),
        gemvermxv2(budget),
        jacobi2d(budget),
        mxv(budget),
        init(budget),
        writeback(budget),
    ]
}

/// Look a kernel up by name.
pub fn kernel_by_name(name: &str, budget: u64) -> Option<PaperKernel> {
    paper_kernels(budget).into_iter().find(|k| k.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kernels_present() {
        let ks = paper_kernels(1 << 24);
        let names: Vec<&str> = ks.iter().map(|k| k.name.as_str()).collect();
        for expect in [
            "bicg",
            "conv",
            "doitgen",
            "gemverouter",
            "gemvermxv1",
            "gemversum",
            "gemvermxv2",
            "jacobi2d",
            "mxv",
            "init",
            "writeback",
        ] {
            assert!(names.contains(&expect), "missing {expect}");
        }
    }

    #[test]
    fn table1_descriptive_columns() {
        let ks = paper_kernels(1 << 24);
        let get = |n: &str| ks.iter().find(|k| k.name == n).unwrap();
        // AT column: stencils unaligned, rest aligned.
        assert!(!get("conv").aligned);
        assert!(!get("jacobi2d").aligned);
        assert!(get("mxv").aligned);
        // IN / WB columns.
        assert!(get("bicg").has_init);
        assert!(get("doitgen").has_init && get("doitgen").has_writeback);
        assert!(get("jacobi2d").has_writeback);
        // LI column.
        assert!(get("gemvermxv1").loop_interchange);
        assert!(get("doitgen").loop_interchange);
        // LB column.
        assert!(get("gemversum").loop_blocking);
        assert!(get("init").loop_blocking);
        assert!(get("writeback").loop_blocking);
        // LE column.
        assert_eq!(get("doitgen").loop_embedment, 2);
        assert_eq!(get("jacobi2d").loop_embedment, 1);
    }

    #[test]
    fn budgets_respected_roughly() {
        for k in paper_kernels(1 << 24) {
            let main: u64 = k.spec.arrays.iter().map(|a| a.bytes()).max().unwrap();
            assert!(
                main <= (1 << 24) + (1 << 22),
                "{}: dominant array {} exceeds budget",
                k.name,
                main
            );
            assert!(main >= 1 << 22, "{}: dominant array {} too small", k.name, main);
        }
    }

    #[test]
    fn extents_divisible_for_sweeps() {
        for k in paper_kernels(1 << 24) {
            for l in &k.spec.loops {
                assert_eq!(l.extent % 64, 0, "{} loop {} extent {}", k.name, l.name, l.extent);
            }
        }
    }

    #[test]
    fn stencil_subscripts_stay_in_bounds() {
        for k in paper_kernels(1 << 22) {
            let maxes: Vec<u64> = k.spec.loops.iter().map(|l| l.extent - 1).collect();
            for acc in &k.spec.accesses {
                assert!(
                    k.spec.address(acc, &maxes).is_some(),
                    "{}: access to array {} out of bounds at loop maxima",
                    k.name,
                    k.spec.arrays[acc.array].name
                );
                let zeros = vec![0u64; k.spec.loops.len()];
                assert!(k.spec.address(acc, &zeros).is_some());
            }
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(kernel_by_name("mxv", 1 << 22).is_some());
        assert!(kernel_by_name("nope", 1 << 22).is_none());
    }
}
