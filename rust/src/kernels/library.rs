//! The kernel universe: the surveyed compute kernels of Table 1 plus an
//! extended family of PolyBench-style memory-bound kernels, all expressed
//! in the loop-nest IR and lowered through the same generic transform.
//!
//! Sizing: each constructor takes a byte budget for the kernel's dominant
//! array (the paper uses 2–4 GiB; the default simulator scale is 48 MiB —
//! see [`crate::config::ScaleConfig`] for why that preserves behaviour).
//! Matrix extents are rounded to multiples of 1024 so every striding
//! configuration the experiments sweep divides them cleanly.
//!
//! [`paper_kernels`] is exactly the Table 1 set (its profiles are pinned by
//! tests); [`extended_kernels`] is the growth set; [`all_kernels`] is the
//! registry-facing union. Adding a kernel means writing one constructor
//! here and appending it to [`extended_kernels`] — the transform, trace
//! lowering, sweeps and report tables pick it up mechanically (see
//! ARCHITECTURE.md §Kernel universe).

use super::spec::{AccessMode, Array, ArrayAccess, IndexExpr, KernelSpec, LoopVar};

/// Metadata mirroring the descriptive columns of Table 1.
#[derive(Debug, Clone)]
pub struct PaperKernel {
    pub name: String,
    pub description: &'static str,
    /// `true` → aligned AVX2 ops (`A` in the table); `false` → unaligned
    /// (`U`; the two stencils, because padding breaks 32-byte alignment).
    pub aligned: bool,
    /// Has an initialization phase (IN column).
    pub has_init: bool,
    /// Has a write-back phase (WB column).
    pub has_writeback: bool,
    /// Loop embedment: number of enclosing outer loops removed for the
    /// isolated experiments (LE column).
    pub loop_embedment: u32,
    /// Loop interchange applied during transformation (LI column).
    pub loop_interchange: bool,
    /// Loop blocking applied (LB column).
    pub loop_blocking: bool,
    /// Paper's data sizes in GiB (isolated, comparison) — for Table 1.
    /// `(0, 0)` for extended kernels the paper did not survey.
    pub data_gib: (f64, f64),
    /// `true` for the extended (beyond-Table-1) kernel family.
    pub extended: bool,
    /// The kernel body.
    pub spec: KernelSpec,
}

/// Square matrix extent for a byte budget, rounded down to a multiple of
/// 1024 (so 1..=32-way striding configs divide it).
fn square_extent(budget_bytes: u64) -> u64 {
    let n = ((budget_bytes / 4) as f64).sqrt() as u64;
    (n / 1024).max(1) * 1024
}

/// 1-D extent for a byte budget, multiple of 1024·64 elements.
fn vec_extent(budget_bytes: u64) -> u64 {
    let n = budget_bytes / 4;
    (n / (1024 * 64)).max(1) * 1024 * 64
}

/// Interior extent of an `n`-wide stencil axis (2 border elements
/// removed), rounded down to a sweep-divisible multiple of 64.
fn interior_extent(n: u64) -> u64 {
    ((n - 2) / 64) * 64
}

fn finished(mut spec: KernelSpec) -> KernelSpec {
    spec.layout();
    spec
}

/// `mxv`: y[i] += A[i][j] · x[j] — dense matrix-vector multiplication.
pub fn mxv(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "mxv".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("x", &[n], 4),
            Array::new("y", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "mxv".into(),
        description: "Matrix Vector Multiplication",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        extended: false,
        spec,
    }
}

/// `bicg`: s[j] += r[i]·A[i][j]; q[i] += A[i][j]·p[j] — the BiCG sub-kernel.
/// `q` accumulates in a register across the row and stores once (the init
/// phase zeroes it), hence its Table 1 classification as a store stream.
pub fn bicg(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "bicg".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("p", &[n], 4),
            Array::new("r", &[n], 4),
            Array::new("s", &[n], 4),
            Array::new("q", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(3, vec![IndexExpr::var(1)], AccessMode::ReadWrite),
            ArrayAccess::new(4, vec![IndexExpr::var(0)], AccessMode::Write),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "bicg".into(),
        description: "BiCG Sub Kernel of BiCGStab Linear Solver",
        aligned: true,
        has_init: true,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        extended: false,
        spec,
    }
}

/// `conv`: 3×3 2-D convolution stencil (valid mode, interior loops so every
/// subscript is non-negative). Unaligned: the ±1-element offsets of the
/// window break 32-byte alignment.
pub fn conv(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let (h, w) = (n, n);
    let mut accesses = Vec::new();
    for di in 0..3i64 {
        for dj in 0..3i64 {
            accesses.push(ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, di), IndexExpr::var_plus(1, dj)],
                AccessMode::Read,
            ));
        }
    }
    accesses.push(ArrayAccess::new(
        1,
        vec![IndexExpr::var(0), IndexExpr::var(1)],
        AccessMode::Write,
    ));
    // Interior extents rounded to sweep-divisible multiples of 64.
    let (ih, iw) = (interior_extent(h), interior_extent(w));
    let spec = finished(KernelSpec {
        name: "conv".into(),
        loops: vec![LoopVar::new("i", ih), LoopVar::new("j", iw)],
        arrays: vec![Array::new("in", &[h, w], 4), Array::new("out", &[h - 2, w - 2], 4)],
        accesses,
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "conv".into(),
        description: "3x3 2D Convolution Stencil",
        aligned: false,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (2.0, 2.0),
        extended: false,
        spec,
    }
}

/// `doitgen` (isolated per §6.1: the two unnecessary outer loops `r, q`
/// removed, init/write-back split off): sum[p] += A[s] · C4[s][p] — after
/// the paper's loop interchange this is the transposed-MxV shape.
pub fn doitgen(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "doitgen".into(),
        loops: vec![LoopVar::new("s", n), LoopVar::new("p", n)],
        arrays: vec![
            Array::new("C4", &[n, n], 4),
            Array::new("A", &[n], 4),
            Array::new("sum", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(1)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "doitgen".into(),
        description: "Multi-resolution analysis kernel (MADNESS)",
        aligned: true,
        has_init: true,
        has_writeback: true,
        loop_embedment: 2,
        loop_interchange: true,
        loop_blocking: false,
        data_gib: (4.0, 0.4),
        extended: false,
        spec,
    }
}

/// `gemverouter`: A[i][j] += u1[i]·v1[j] + u2[i]·v2[j] — double rank-1
/// update.
pub fn gemverouter(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "gemverouter".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("u1", &[n], 4),
            Array::new("v1", &[n], 4),
            Array::new("u2", &[n], 4),
            Array::new("v2", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::ReadWrite),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(3, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(4, vec![IndexExpr::var(1)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "gemverouter".into(),
        description: "Double Rank-1 Matrix Update",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        extended: false,
        spec,
    }
}

/// `gemvermxv1`: x[i] += β·A[j][i]·y[j] — *transposed* matrix-vector
/// multiplication (the paper's Listing 1; requires loop interchange).
pub fn gemvermxv1(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "gemvermxv1".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("y", &[n], 4),
            Array::new("x", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(1), IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "gemvermxv1".into(),
        description: "Transpose Matrix Vector Multiplication",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: true,
        loop_blocking: false,
        data_gib: (4.0, 4.0),
        extended: false,
        spec,
    }
}

/// `gemversum`: x[i] = x[i] + z[i] — vector sum update (1-D; needs loop
/// blocking to create strides). The x stream reads and writes the same
/// positions; Table 1 lists it under separate L and S columns, our profiler
/// reports it as a combined L/S stream (same information).
pub fn gemversum(budget: u64) -> PaperKernel {
    let n = vec_extent(budget / 2);
    let spec = finished(KernelSpec {
        name: "gemversum".into(),
        loops: vec![LoopVar::new("i", n)],
        arrays: vec![Array::new("x", &[n], 4), Array::new("z", &[n], 4)],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "gemversum".into(),
        description: "Vector Sum Update",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (4.0, 4.0),
        extended: false,
        spec,
    }
}

/// `gemvermxv2`: w[i] += α·A[i][j]·x[j] — plain matrix-vector
/// multiplication (same shape as `mxv`).
pub fn gemvermxv2(budget: u64) -> PaperKernel {
    let mut k = mxv(budget);
    k.name = "gemvermxv2".into();
    k.spec.name = "gemvermxv2".into();
    k.description = "Matrix Vector Multiplication";
    k
}

/// `jacobi2d`: B[i+1][j+1] = 0.2·(A[i+1][j+1] + A[i+1][j] + A[i+1][j+2] +
/// A[i][j+1] + A[i+2][j+1]) — 5-point stencil over the interior.
pub fn jacobi2d(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let (h, w) = (n, n);
    let (ih, iw) = (interior_extent(h), interior_extent(w));
    let spec = finished(KernelSpec {
        name: "jacobi2d".into(),
        loops: vec![LoopVar::new("i", ih), LoopVar::new("j", iw)],
        arrays: vec![Array::new("A", &[h, w], 4), Array::new("B", &[h, w], 4)],
        accesses: vec![
            // Center + four neighbours (all offsets non-negative: interior).
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 1)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 0)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 2)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 0), IndexExpr::var_plus(1, 1)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                0,
                vec![IndexExpr::var_plus(0, 2), IndexExpr::var_plus(1, 1)],
                AccessMode::Read,
            ),
            ArrayAccess::new(
                1,
                vec![IndexExpr::var_plus(0, 1), IndexExpr::var_plus(1, 1)],
                AccessMode::Write,
            ),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "jacobi2d".into(),
        description: "2D Jacobi Stencil",
        aligned: false,
        has_init: false,
        has_writeback: true,
        loop_embedment: 1,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (2.0, 2.0),
        extended: false,
        spec,
    }
}

/// `init`: A[i] = 0 — the initialization phase kernel (1-D, loop blocked).
pub fn init(budget: u64) -> PaperKernel {
    let n = vec_extent(budget);
    let spec = finished(KernelSpec {
        name: "init".into(),
        loops: vec![LoopVar::new("i", n)],
        arrays: vec![Array::new("A", &[n], 4)],
        accesses: vec![ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Write)],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "init".into(),
        description: "Initialization",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (2.0, 2.0),
        extended: false,
        spec,
    }
}

/// `writeback`: A[i] = B[i] — the write-back phase kernel (1-D copy).
pub fn writeback(budget: u64) -> PaperKernel {
    let n = vec_extent(budget / 2);
    let spec = finished(KernelSpec {
        name: "writeback".into(),
        loops: vec![LoopVar::new("i", n)],
        arrays: vec![Array::new("A", &[n], 4), Array::new("B", &[n], 4)],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Write),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "writeback".into(),
        description: "Writeback",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (2.0, 2.0),
        extended: false,
        spec,
    }
}

// ---------------------------------------------------------------------------
// Extended kernel universe: PolyBench-style memory-bound kernels beyond
// Table 1. No per-kernel lowering exists anywhere — each is only a spec;
// the generic transform derives its single-stride baseline and S ∈ {2,4,8}
// multi-strided variants (see `transform::variants`).
// ---------------------------------------------------------------------------

/// `3mm`: C[i][j] += A[i][k] · B[k][j] — one matrix-multiply stage of
/// PolyBench `3mm`, restricted to a rank-8 panel (K = 8, outermost) so the
/// trace volume stays within a small constant factor of the 2-D kernels.
/// The first 3-deep nest in the library: striding unrolls the row loop `i`,
/// giving S concurrent C/A row streams against a B row shared across
/// replicas — the multi-strided GEMM schedule.
pub fn mm3(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    const K: u64 = 8;
    let spec = finished(KernelSpec {
        name: "3mm".into(),
        loops: vec![LoopVar::new("k", K), LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, K], 4),
            Array::new("B", &[K, n], 4),
            Array::new("C", &[n, n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(1), IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(0), IndexExpr::var(2)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(1), IndexExpr::var(2)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "3mm".into(),
        description: "PolyBench 3mm stage (rank-8 panel GEMM)",
        aligned: true,
        has_init: true,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (0.0, 0.0),
        extended: true,
        spec,
    }
}

/// `atax`: y[j] += A[i][j]·tmp[i] — the second phase of PolyBench `atax`
/// (y = Aᵀ·(A·x)), isolated per the repo's gemver precedent: the first
/// phase (`tmp = A·x`, an mxv shape already covered by `mxv`) must
/// complete before this one, so fusing the two nests would carry a flow
/// dependence through `tmp` and §5.1 would reject it. Isolated, `tmp` is
/// a pure input broadcast per row and `y[j]` a streamed reduction — the
/// transposed update shape of bicg's `s` stream, without its second
/// accumulator.
pub fn atax(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let spec = finished(KernelSpec {
        name: "atax".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![
            Array::new("A", &[n, n], 4),
            Array::new("tmp", &[n], 4),
            Array::new("y", &[n], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(1)], AccessMode::ReadWrite),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "atax".into(),
        description: "Matrix Transpose Vector Update, atax phase 2 (PolyBench)",
        aligned: true,
        has_init: true,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (0.0, 0.0),
        extended: true,
        spec,
    }
}

/// `fdtd2d`: the magnetic-field update of the PolyBench 2-D
/// finite-difference time-domain kernel — `hz[i][j] -= 0.7·(ex[i][j+1] −
/// ex[i][j] + ey[i+1][j] − ey[i][j])` over the interior (subscripts
/// shifted by +1 so every offset is non-negative). Only this statement of
/// the fdtd-2d time step is dependence-free when isolated (the fused
/// three-statement body carries flow dependences between the field
/// arrays, which §5.1 excludes — same isolation the paper applies via its
/// LE column). Unaligned like the stencils: the ±1-element window breaks
/// 32-byte alignment.
pub fn fdtd2d(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    let (h, w) = (n, n);
    let (ih, iw) = (interior_extent(h), interior_extent(w));
    let c = |di: i64, dj: i64| vec![IndexExpr::var_plus(0, 1 + di), IndexExpr::var_plus(1, 1 + dj)];
    let spec = finished(KernelSpec {
        name: "fdtd2d".into(),
        loops: vec![LoopVar::new("i", ih), LoopVar::new("j", iw)],
        arrays: vec![
            Array::new("ex", &[h, w], 4),
            Array::new("ey", &[h, w], 4),
            Array::new("hz", &[h, w], 4),
        ],
        accesses: vec![
            ArrayAccess::new(2, c(0, 0), AccessMode::ReadWrite),
            ArrayAccess::new(0, c(0, 0), AccessMode::Read),
            ArrayAccess::new(0, c(0, 1), AccessMode::Read),
            ArrayAccess::new(1, c(0, 0), AccessMode::Read),
            ArrayAccess::new(1, c(1, 0), AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "fdtd2d".into(),
        description: "2D FDTD magnetic-field update (PolyBench fdtd-2d)",
        aligned: false,
        has_init: false,
        has_writeback: false,
        loop_embedment: 1,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (0.0, 0.0),
        extended: true,
        spec,
    }
}

/// `jacobi1d`: B[i+1] = ⅓·(A[i] + A[i+1] + A[i+2]) — the 1-D 3-point
/// Jacobi stencil (PolyBench `jacobi-1d`). One loop, so the transform's
/// loop blocking creates the stride axis, and the ±1-element window makes
/// it the only *unaligned blocked* kernel in the universe.
pub fn jacobi1d(budget: u64) -> PaperKernel {
    let e = vec_extent(budget);
    let spec = finished(KernelSpec {
        name: "jacobi1d".into(),
        loops: vec![LoopVar::new("i", e)],
        arrays: vec![Array::new("A", &[e + 2], 4), Array::new("B", &[e + 2], 4)],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(0, vec![IndexExpr::var_plus(0, 1)], AccessMode::Read),
            ArrayAccess::new(0, vec![IndexExpr::var_plus(0, 2)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var_plus(0, 1)], AccessMode::Write),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "jacobi1d".into(),
        description: "1D Jacobi Stencil (PolyBench)",
        aligned: false,
        has_init: false,
        has_writeback: true,
        loop_embedment: 1,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (0.0, 0.0),
        extended: true,
        spec,
    }
}

/// `stridedcopy`: dst[i][j] = src[i][j] where the source rows carry a
/// 512-byte pitch pad — a 2-D sub-matrix memcpy. Even the single-stride
/// baseline walks two streams whose row advances jump by different pitches,
/// which is exactly the access shape DMA-style copies hand the prefetcher.
pub fn stridedcopy(budget: u64) -> PaperKernel {
    let n = square_extent(budget);
    const PITCH_PAD: u64 = 128; // elements of row padding (512 B)
    let spec = finished(KernelSpec {
        name: "stridedcopy".into(),
        loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
        arrays: vec![Array::new("src", &[n, n + PITCH_PAD], 4), Array::new("dst", &[n, n], 4)],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
            ArrayAccess::new(1, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Write),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "stridedcopy".into(),
        description: "Strided row copy (2D memcpy with row pitch)",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: false,
        data_gib: (0.0, 0.0),
        extended: true,
        spec,
    }
}

/// `triad`: a[i] = b[i] + α·c[i] — the STREAM triad (1-D, loop blocked):
/// two load streams against one store stream per stride replica.
pub fn triad(budget: u64) -> PaperKernel {
    let e = vec_extent(budget / 3);
    let spec = finished(KernelSpec {
        name: "triad".into(),
        loops: vec![LoopVar::new("i", e)],
        arrays: vec![
            Array::new("a", &[e], 4),
            Array::new("b", &[e], 4),
            Array::new("c", &[e], 4),
        ],
        accesses: vec![
            ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Write),
            ArrayAccess::new(1, vec![IndexExpr::var(0)], AccessMode::Read),
            ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::Read),
        ],
        loop_carried_dep: false,
    });
    PaperKernel {
        name: "triad".into(),
        description: "STREAM Triad",
        aligned: true,
        has_init: false,
        has_writeback: false,
        loop_embedment: 0,
        loop_interchange: false,
        loop_blocking: true,
        data_gib: (0.0, 0.0),
        extended: true,
        spec,
    }
}

/// All Table 1 kernels (six surveyed kernels with gemver's four parts,
/// plus the init/writeback phase kernels), dominant array sized to
/// `budget` bytes.
pub fn paper_kernels(budget: u64) -> Vec<PaperKernel> {
    vec![
        bicg(budget),
        conv(budget),
        doitgen(budget),
        gemverouter(budget),
        gemvermxv1(budget),
        gemversum(budget),
        gemvermxv2(budget),
        jacobi2d(budget),
        mxv(budget),
        init(budget),
        writeback(budget),
    ]
}

/// The extended (beyond-Table-1) kernel family.
pub fn extended_kernels(budget: u64) -> Vec<PaperKernel> {
    vec![
        mm3(budget),
        atax(budget),
        fdtd2d(budget),
        jacobi1d(budget),
        stridedcopy(budget),
        triad(budget),
    ]
}

/// The whole kernel universe: Table 1 + extended family.
pub fn all_kernels(budget: u64) -> Vec<PaperKernel> {
    let mut v = paper_kernels(budget);
    v.extend(extended_kernels(budget));
    v
}

/// Look a kernel up by name, across the whole universe.
pub fn kernel_by_name(name: &str, budget: u64) -> Option<PaperKernel> {
    all_kernels(budget).into_iter().find(|k| k.name == name)
}

/// Clean CLI-boundary check for kernel-scoped commands: `Ok` for `None`
/// (no restriction) or a registered name; an unknown name errors with
/// the whole registered universe (names + family + description) so the
/// user sees what *is* available — the same policy as the unknown
/// `--machine` listing (`MachinePreset::from_name_or_listing`).
pub fn ensure_known_kernel(kernel: Option<&str>, budget: u64) -> crate::Result<()> {
    let Some(k) = kernel else { return Ok(()) };
    if kernel_by_name(k, budget).is_some() {
        return Ok(());
    }
    let mut listing = String::new();
    for pk in all_kernels(budget) {
        listing.push_str(&format!(
            "\n  {:<12} [{}] {}",
            pk.name,
            if pk.extended { "extended" } else { "paper" },
            pk.description
        ));
    }
    crate::bail!("unknown kernel {k}; the registered kernel universe is:{listing}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_kernel_error_lists_the_whole_universe() {
        // The `--kernel` boundary: a typo must come back with the full
        // registered universe, never an empty sweep or a bare panic.
        let budget = 1 << 20;
        let err = ensure_known_kernel(Some("nope"), budget).unwrap_err().to_string();
        assert!(err.contains("unknown kernel nope"), "{err}");
        for pk in all_kernels(budget) {
            assert!(err.contains(&pk.name), "listing must include {}: {err}", pk.name);
        }
        assert!(err.contains("[extended]") && err.contains("[paper]"), "{err}");
        // No restriction and known names pass.
        assert!(ensure_known_kernel(None, budget).is_ok());
        assert!(ensure_known_kernel(Some("mxv"), budget).is_ok());
        assert!(ensure_known_kernel(Some("3mm"), budget).is_ok());
    }

    #[test]
    fn all_kernels_present() {
        let ks = paper_kernels(1 << 24);
        let names: Vec<&str> = ks.iter().map(|k| k.name.as_str()).collect();
        for expect in [
            "bicg",
            "conv",
            "doitgen",
            "gemverouter",
            "gemvermxv1",
            "gemversum",
            "gemvermxv2",
            "jacobi2d",
            "mxv",
            "init",
            "writeback",
        ] {
            assert!(names.contains(&expect), "missing {expect}");
        }
    }

    #[test]
    fn table1_descriptive_columns() {
        let ks = paper_kernels(1 << 24);
        let get = |n: &str| ks.iter().find(|k| k.name == n).unwrap();
        // AT column: stencils unaligned, rest aligned.
        assert!(!get("conv").aligned);
        assert!(!get("jacobi2d").aligned);
        assert!(get("mxv").aligned);
        // IN / WB columns.
        assert!(get("bicg").has_init);
        assert!(get("doitgen").has_init && get("doitgen").has_writeback);
        assert!(get("jacobi2d").has_writeback);
        // LI column.
        assert!(get("gemvermxv1").loop_interchange);
        assert!(get("doitgen").loop_interchange);
        // LB column.
        assert!(get("gemversum").loop_blocking);
        assert!(get("init").loop_blocking);
        assert!(get("writeback").loop_blocking);
        // LE column.
        assert_eq!(get("doitgen").loop_embedment, 2);
        assert_eq!(get("jacobi2d").loop_embedment, 1);
    }

    #[test]
    fn budgets_respected_roughly() {
        for k in paper_kernels(1 << 24) {
            let main: u64 = k.spec.arrays.iter().map(|a| a.bytes()).max().unwrap();
            assert!(
                main <= (1 << 24) + (1 << 22),
                "{}: dominant array {} exceeds budget",
                k.name,
                main
            );
            assert!(main >= 1 << 22, "{}: dominant array {} too small", k.name, main);
        }
    }

    #[test]
    fn extents_divisible_for_sweeps() {
        for k in paper_kernels(1 << 24) {
            for l in &k.spec.loops {
                assert_eq!(l.extent % 64, 0, "{} loop {} extent {}", k.name, l.name, l.extent);
            }
        }
    }

    #[test]
    fn stencil_subscripts_stay_in_bounds() {
        for k in paper_kernels(1 << 22) {
            let maxes: Vec<u64> = k.spec.loops.iter().map(|l| l.extent - 1).collect();
            for acc in &k.spec.accesses {
                assert!(
                    k.spec.address(acc, &maxes).is_some(),
                    "{}: access to array {} out of bounds at loop maxima",
                    k.name,
                    k.spec.arrays[acc.array].name
                );
                let zeros = vec![0u64; k.spec.loops.len()];
                assert!(k.spec.address(acc, &zeros).is_some());
            }
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(kernel_by_name("mxv", 1 << 22).is_some());
        assert!(kernel_by_name("nope", 1 << 22).is_none());
        // Extended kernels resolve through the same lookup.
        assert!(kernel_by_name("3mm", 1 << 22).is_some());
        assert!(kernel_by_name("triad", 1 << 22).is_some());
    }

    #[test]
    fn universe_is_paper_plus_extended() {
        let budget = 1 << 24;
        let all = all_kernels(budget);
        assert_eq!(all.len(), paper_kernels(budget).len() + extended_kernels(budget).len());
        for k in ["3mm", "atax", "fdtd2d", "jacobi1d", "stridedcopy", "triad"] {
            let pk = all.iter().find(|p| p.name == k).unwrap_or_else(|| panic!("missing {k}"));
            assert!(pk.extended, "{k} must be flagged extended");
        }
        let mut core = all.iter().filter(|k| !k.extended);
        assert!(core.all(|k| table_names().contains(&k.name.as_str())));
    }

    fn table_names() -> Vec<&'static str> {
        vec![
            "bicg",
            "conv",
            "doitgen",
            "gemverouter",
            "gemvermxv1",
            "gemversum",
            "gemvermxv2",
            "jacobi2d",
            "mxv",
            "init",
            "writeback",
        ]
    }

    #[test]
    fn extended_subscripts_stay_in_bounds() {
        for k in extended_kernels(1 << 22) {
            let maxes: Vec<u64> = k.spec.loops.iter().map(|l| l.extent - 1).collect();
            let zeros = vec![0u64; k.spec.loops.len()];
            for acc in &k.spec.accesses {
                assert!(
                    k.spec.address(acc, &maxes).is_some(),
                    "{}: access to {} out of bounds at loop maxima",
                    k.name,
                    k.spec.arrays[acc.array].name
                );
                assert!(k.spec.address(acc, &zeros).is_some());
            }
        }
    }

    #[test]
    fn extended_budgets_roughly_respected() {
        let budget = 1u64 << 24;
        for k in extended_kernels(budget) {
            let main: u64 = k.spec.arrays.iter().map(|a| a.bytes()).max().unwrap();
            assert!(main >= budget / 8, "{}: dominant array {} too small", k.name, main);
            assert!(main <= 2 * budget, "{}: dominant array {} too large", k.name, main);
        }
    }
}
