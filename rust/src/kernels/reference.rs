//! Access-pattern models of the state-of-the-art reference implementations
//! Figure 7 compares against.
//!
//! **Substitution notice (DESIGN.md §2):** the paper benchmarks vendor
//! binaries (MKL 2024.2, OpenBLAS 0.3.28, Halide 18, OpenCV 4.10, CLang /
//! Polly 20). Those are unavailable here, and what Figure 7 actually
//! compares is *memory access schedules* — so each reference is modeled as
//! the striding/blocking schedule its implementation documents or its
//! generated code exhibits. Each model reduces to a [`StridingConfig`] (or
//! a small schedule variation) applied to the same kernel spec, so the
//! comparison isolates exactly what the paper isolates: the access pattern.

use crate::transform::StridingConfig;
use crate::trace::Arrangement;

/// A reference implementation modeled by its memory access schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reference {
    /// `clang -O3` auto-vectorized: single stride, 4-way portion unroll
    /// (LLVM's default interleave factor for these loops).
    Clang,
    /// `clang -O3 -mllvm -polly` with strip-mine vectorizer: tiles the loop
    /// nest; the inner tile walks a single stride with 1-way unroll. (The
    /// paper verified Polly emitted no AVX2 for bicg/mxv on these kernels —
    /// modeled as scalar-width vectors, i.e. effectively narrow accesses.)
    Polly,
    /// Generated assembly with no unrolling at all (the paper's red line).
    NoUnroll,
    /// The best single-strided generated assembly (the paper's green line).
    BestSingleStrided,
    /// Intel MKL gemv-class schedule: single contiguous sweep with heavy
    /// portion unroll (8) and software-pipelined accumulators.
    Mkl,
    /// OpenBLAS gemv-class schedule: 2 concurrent row strides (its kernels
    /// process two rows per iteration), portion unroll 4.
    OpenBlas,
    /// Halide with the Mullapudi2016 autoscheduler: tiled, 1 stride,
    /// unroll 2.
    HalideMullapudi,
    /// Halide with the Adams2019 autoscheduler: tiled, 2 strides, unroll 4.
    HalideAdams,
    /// Halide with the Li2018 autoscheduler: simple schedule, 1 stride,
    /// unroll 1.
    HalideLi,
    /// OpenCV filter2D: row-by-row single stride, unroll 2.
    OpenCv,
}

impl Reference {
    /// All references applicable to a given kernel (the paper compares
    /// BLAS-class kernels against MKL/OpenBLAS and stencils against
    /// Halide/OpenCV; every kernel gets CLang/Polly/NoUnroll/SingleStrided).
    pub fn for_kernel(kernel: &str) -> Vec<Reference> {
        let mut v = vec![
            Reference::Clang,
            Reference::Polly,
            Reference::NoUnroll,
            Reference::BestSingleStrided,
        ];
        match kernel {
            "bicg" | "doitgen" | "gemver" | "gemverouter" | "gemvermxv1" | "gemvermxv2"
            | "gemversum" | "mxv" => {
                v.push(Reference::Mkl);
                v.push(Reference::OpenBlas);
            }
            "conv" => {
                v.push(Reference::HalideMullapudi);
                v.push(Reference::HalideAdams);
                v.push(Reference::HalideLi);
                v.push(Reference::OpenCv);
            }
            "jacobi2d" => {
                v.push(Reference::HalideMullapudi);
                v.push(Reference::HalideAdams);
                v.push(Reference::HalideLi);
            }
            _ => {}
        }
        v
    }

    /// The access schedule this reference runs, as a striding config over
    /// the shared kernel spec. `BestSingleStrided` is resolved by sweeping
    /// portion unrolls (the coordinator does that); the value here is its
    /// schedule family.
    pub fn schedule(self) -> StridingConfig {
        let mut c = match self {
            Reference::Clang => StridingConfig::new(1, 4),
            // Polly's strip-mined scalar loops: model as no unrolling (its
            // lost vectorization shows up as issue-rate, handled by the
            // scalar_width flag below).
            Reference::Polly => StridingConfig::new(1, 1),
            Reference::NoUnroll => StridingConfig::new(1, 1),
            Reference::BestSingleStrided => StridingConfig::new(1, 8),
            Reference::Mkl => StridingConfig::new(1, 8),
            Reference::OpenBlas => StridingConfig::new(2, 4),
            Reference::HalideMullapudi => StridingConfig::new(1, 2),
            Reference::HalideAdams => StridingConfig::new(2, 4),
            Reference::HalideLi => StridingConfig::new(1, 1),
            Reference::OpenCv => StridingConfig::new(1, 2),
        };
        // Hand-optimized libraries eliminate redundant accesses.
        c.eliminate_redundant = matches!(
            self,
            Reference::Mkl
                | Reference::OpenBlas
                | Reference::HalideMullapudi
                | Reference::HalideAdams
                | Reference::OpenCv
        );
        c.arrangement = Arrangement::Grouped;
        c
    }

    /// Some references fail to vectorize certain kernels (the paper: Polly
    /// emitted no AVX2 for bicg and mxv; plain CLang none for mxv). Scalar
    /// code moves 4 bytes per issue slot instead of 32 — an 8× issue-rate
    /// handicap on the same access footprint.
    pub fn scalar_on(self, kernel: &str) -> bool {
        match self {
            Reference::Polly => matches!(kernel, "bicg" | "mxv"),
            Reference::Clang => kernel == "mxv",
            _ => false,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Reference::Clang => "CLang",
            Reference::Polly => "Polly",
            Reference::NoUnroll => "no-unroll",
            Reference::BestSingleStrided => "best single-strided",
            Reference::Mkl => "MKL (model)",
            Reference::OpenBlas => "OpenBLAS (model)",
            Reference::HalideMullapudi => "Halide/Mullapudi (model)",
            Reference::HalideAdams => "Halide/Adams (model)",
            Reference::HalideLi => "Halide/Li (model)",
            Reference::OpenCv => "OpenCV (model)",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blas_refs_attached_to_blas_kernels() {
        let refs = Reference::for_kernel("mxv");
        assert!(refs.contains(&Reference::Mkl));
        assert!(refs.contains(&Reference::OpenBlas));
        assert!(!refs.contains(&Reference::OpenCv));
    }

    #[test]
    fn stencil_refs_attached_to_stencils() {
        let refs = Reference::for_kernel("conv");
        assert!(refs.contains(&Reference::OpenCv));
        assert!(refs.contains(&Reference::HalideAdams));
        assert!(!refs.contains(&Reference::Mkl));
        let refs = Reference::for_kernel("jacobi2d");
        assert!(refs.contains(&Reference::HalideLi));
        assert!(!refs.contains(&Reference::OpenCv), "paper only compares conv to OpenCV");
    }

    #[test]
    fn every_kernel_gets_compiler_baselines() {
        for k in ["mxv", "conv", "jacobi2d", "bicg", "gemversum"] {
            let refs = Reference::for_kernel(k);
            assert!(refs.contains(&Reference::Clang));
            assert!(refs.contains(&Reference::Polly));
            assert!(refs.contains(&Reference::NoUnroll));
            assert!(refs.contains(&Reference::BestSingleStrided));
        }
    }

    #[test]
    fn reference_schedules_are_at_most_two_strides() {
        // No reference implementation multi-strides beyond OpenBLAS's
        // two-row kernels — that is the paper's point.
        for r in [
            Reference::Clang,
            Reference::Polly,
            Reference::Mkl,
            Reference::OpenBlas,
            Reference::HalideMullapudi,
            Reference::HalideAdams,
            Reference::HalideLi,
            Reference::OpenCv,
        ] {
            assert!(r.schedule().stride_unroll <= 2, "{:?}", r);
        }
    }

    #[test]
    fn scalar_fallbacks_match_paper_observations() {
        assert!(Reference::Polly.scalar_on("bicg"));
        assert!(Reference::Polly.scalar_on("mxv"));
        assert!(!Reference::Polly.scalar_on("conv"));
        assert!(Reference::Clang.scalar_on("mxv"));
        assert!(!Reference::Mkl.scalar_on("mxv"));
    }
}
