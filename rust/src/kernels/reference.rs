//! Reference models: access-pattern models of the state-of-the-art
//! implementations Figure 7 compares against, plus [`interp`] — the
//! order-independent numeric reference execution that the
//! transform-correctness oracle (`tests/transform_oracle.rs`) pins every
//! derived variant against.
//!
//! **Substitution notice (DESIGN.md §2):** the paper benchmarks vendor
//! binaries (MKL 2024.2, OpenBLAS 0.3.28, Halide 18, OpenCV 4.10, CLang /
//! Polly 20). Those are unavailable here, and what Figure 7 actually
//! compares is *memory access schedules* — so each reference is modeled as
//! the striding/blocking schedule its implementation documents or its
//! generated code exhibits. Each model reduces to a [`StridingConfig`] (or
//! a small schedule variation) applied to the same kernel spec, so the
//! comparison isolates exactly what the paper isolates: the access pattern.

use crate::transform::StridingConfig;
use crate::trace::Arrangement;

/// A reference implementation modeled by its memory access schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reference {
    /// `clang -O3` auto-vectorized: single stride, 4-way portion unroll
    /// (LLVM's default interleave factor for these loops).
    Clang,
    /// `clang -O3 -mllvm -polly` with strip-mine vectorizer: tiles the loop
    /// nest; the inner tile walks a single stride with 1-way unroll. (The
    /// paper verified Polly emitted no AVX2 for bicg/mxv on these kernels —
    /// modeled as scalar-width vectors, i.e. effectively narrow accesses.)
    Polly,
    /// Generated assembly with no unrolling at all (the paper's red line).
    NoUnroll,
    /// The best single-strided generated assembly (the paper's green line).
    BestSingleStrided,
    /// Intel MKL gemv-class schedule: single contiguous sweep with heavy
    /// portion unroll (8) and software-pipelined accumulators.
    Mkl,
    /// OpenBLAS gemv-class schedule: 2 concurrent row strides (its kernels
    /// process two rows per iteration), portion unroll 4.
    OpenBlas,
    /// Halide with the Mullapudi2016 autoscheduler: tiled, 1 stride,
    /// unroll 2.
    HalideMullapudi,
    /// Halide with the Adams2019 autoscheduler: tiled, 2 strides, unroll 4.
    HalideAdams,
    /// Halide with the Li2018 autoscheduler: simple schedule, 1 stride,
    /// unroll 1.
    HalideLi,
    /// OpenCV filter2D: row-by-row single stride, unroll 2.
    OpenCv,
}

impl Reference {
    /// The compiler baselines every kernel gets — the single source of
    /// truth for the baseline/vendor split, shared by [`for_kernel`] and
    /// [`is_vendor_model`].
    ///
    /// [`for_kernel`]: Reference::for_kernel
    /// [`is_vendor_model`]: Reference::is_vendor_model
    pub const COMPILER_BASELINES: [Reference; 4] = [
        Reference::Clang,
        Reference::Polly,
        Reference::NoUnroll,
        Reference::BestSingleStrided,
    ];

    /// Is this a vendor library model (MKL/OpenBLAS/Halide/OpenCV), as
    /// opposed to one of the compiler baselines every kernel gets?
    pub fn is_vendor_model(self) -> bool {
        !Self::COMPILER_BASELINES.contains(&self)
    }

    /// All references applicable to a given kernel (the paper compares
    /// BLAS-class kernels against MKL/OpenBLAS and stencils against
    /// Halide/OpenCV; every kernel gets CLang/Polly/NoUnroll/SingleStrided).
    pub fn for_kernel(kernel: &str) -> Vec<Reference> {
        let mut v = Self::COMPILER_BASELINES.to_vec();
        match kernel {
            // BLAS-class kernels (including the extended GEMM/atax family).
            "bicg" | "doitgen" | "gemver" | "gemverouter" | "gemvermxv1" | "gemvermxv2"
            | "gemversum" | "mxv" | "3mm" | "atax" => {
                v.push(Reference::Mkl);
                v.push(Reference::OpenBlas);
            }
            "conv" => {
                v.push(Reference::HalideMullapudi);
                v.push(Reference::HalideAdams);
                v.push(Reference::HalideLi);
                v.push(Reference::OpenCv);
            }
            // Stencil-class kernels compare against the Halide schedules.
            "jacobi2d" | "fdtd2d" | "jacobi1d" => {
                v.push(Reference::HalideMullapudi);
                v.push(Reference::HalideAdams);
                v.push(Reference::HalideLi);
            }
            // Pure data-movement micros (stridedcopy, triad) only have the
            // compiler baselines.
            _ => {}
        }
        v
    }

    /// The access schedule this reference runs, as a striding config over
    /// the shared kernel spec. `BestSingleStrided` is resolved by sweeping
    /// portion unrolls (the coordinator does that); the value here is its
    /// schedule family.
    pub fn schedule(self) -> StridingConfig {
        let mut c = match self {
            Reference::Clang => StridingConfig::new(1, 4),
            // Polly's strip-mined scalar loops: model as no unrolling (its
            // lost vectorization shows up as issue-rate, handled by the
            // scalar_width flag below).
            Reference::Polly => StridingConfig::new(1, 1),
            Reference::NoUnroll => StridingConfig::new(1, 1),
            Reference::BestSingleStrided => StridingConfig::new(1, 8),
            Reference::Mkl => StridingConfig::new(1, 8),
            Reference::OpenBlas => StridingConfig::new(2, 4),
            Reference::HalideMullapudi => StridingConfig::new(1, 2),
            Reference::HalideAdams => StridingConfig::new(2, 4),
            Reference::HalideLi => StridingConfig::new(1, 1),
            Reference::OpenCv => StridingConfig::new(1, 2),
        };
        // Hand-optimized libraries eliminate redundant accesses.
        c.eliminate_redundant = matches!(
            self,
            Reference::Mkl
                | Reference::OpenBlas
                | Reference::HalideMullapudi
                | Reference::HalideAdams
                | Reference::OpenCv
        );
        c.arrangement = Arrangement::Grouped;
        c
    }

    /// Some references fail to vectorize certain kernels (the paper: Polly
    /// emitted no AVX2 for bicg and mxv; plain CLang none for mxv). Scalar
    /// code moves 4 bytes per issue slot instead of 32 — an 8× issue-rate
    /// handicap on the same access footprint.
    pub fn scalar_on(self, kernel: &str) -> bool {
        match self {
            Reference::Polly => matches!(kernel, "bicg" | "mxv"),
            Reference::Clang => kernel == "mxv",
            _ => false,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Reference::Clang => "CLang",
            Reference::Polly => "Polly",
            Reference::NoUnroll => "no-unroll",
            Reference::BestSingleStrided => "best single-strided",
            Reference::Mkl => "MKL (model)",
            Reference::OpenBlas => "OpenBLAS (model)",
            Reference::HalideMullapudi => "Halide/Mullapudi (model)",
            Reference::HalideAdams => "Halide/Adams (model)",
            Reference::HalideLi => "Halide/Li (model)",
            Reference::OpenCv => "OpenCV (model)",
        }
    }
}

/// Order-independent numeric interpreter for kernel specs — the
/// transform-correctness oracle's execution model.
///
/// The striding transform is only allowed to *reorder* a dependence-free
/// iteration space. To check that bit-exactly without floating-point
/// rounding being order-sensitive, this interpreter gives every kernel a
/// synthetic commutative semantics over `u64`s:
///
/// * untouched memory reads as a deterministic hash of its address
///   ([`interp::initial`]);
/// * at each iteration point, the reads of **pure input** arrays (arrays
///   no access ever writes) fold into a per-point contribution;
/// * every written element *accumulates* (wrapping add) the contribution
///   mixed with its own address.
///
/// Wrapping addition is commutative and associative, so any execution
/// order over the same iteration multiset yields the bit-identical final
/// memory — while a transform that drops, duplicates or mis-addresses an
/// iteration point changes it. `tests/transform_oracle.rs` uses this to
/// pin every derived variant against the untransformed source nest.
pub mod interp {
    use std::collections::HashMap;

    use crate::kernels::spec::{AccessMode, KernelSpec};
    use crate::transform::{Transformed, VEC_ELEMS};

    /// splitmix64 finalizer: the mixing primitive.
    fn mix(mut z: u64) -> u64 {
        z = z.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Deterministic "input data" for an address never written.
    pub fn initial(addr: u64) -> u64 {
        mix(addr ^ 0x5EED_5EED_5EED_5EED)
    }

    /// Final memory state: element byte address → value.
    pub type Memory = HashMap<u64, u64>;

    /// Which accesses read *pure input* arrays (never written by any
    /// access of the spec)?
    fn pure_inputs(spec: &KernelSpec) -> Vec<bool> {
        let mut written = vec![false; spec.arrays.len()];
        for a in &spec.accesses {
            if a.mode != AccessMode::Read {
                written[a.array] = true;
            }
        }
        spec.accesses.iter().map(|a| !written[a.array]).collect()
    }

    /// Apply the body once at concrete loop values.
    fn body(spec: &KernelSpec, pure: &[bool], mem: &mut Memory, vals: &[u64]) {
        let mut contrib = 0x9e3779b97f4a7c15u64;
        for (ai, acc) in spec.accesses.iter().enumerate() {
            if acc.mode == AccessMode::Read && pure[ai] {
                if let Some(addr) = spec.address(acc, vals) {
                    // Pure-input arrays are never written, so their value
                    // is always the synthetic initial data — by invariant,
                    // not a memory probe.
                    contrib = mix(contrib ^ initial(addr));
                }
            }
        }
        for acc in &spec.accesses {
            if acc.mode == AccessMode::Read {
                continue;
            }
            if let Some(addr) = spec.address(acc, vals) {
                let old = mem.get(&addr).copied().unwrap_or_else(|| initial(addr));
                mem.insert(addr, old.wrapping_add(mix(contrib ^ mix(addr))));
            }
        }
    }

    /// Execute the *source-order* nest at element granularity.
    pub fn execute_source(spec: &KernelSpec) -> Memory {
        let pure = pure_inputs(spec);
        let mut mem = Memory::new();
        if spec.loops.iter().any(|l| l.extent == 0) {
            return mem;
        }
        let mut vals = vec![0u64; spec.loops.len()];
        loop {
            body(spec, &pure, &mut mem, &vals);
            let mut i = spec.loops.len();
            loop {
                if i == 0 {
                    return mem;
                }
                i -= 1;
                vals[i] += 1;
                if vals[i] < spec.loops[i].extent {
                    break;
                }
                vals[i] = 0;
            }
        }
    }

    /// Execute a transformed kernel in its *transformed* visit order
    /// (interchanged loop order, stride replicas and portion slots unrolled
    /// in the body), at element granularity.
    pub fn execute_transformed(t: &Transformed) -> Memory {
        let spec = &t.spec;
        let pure = pure_inputs(spec);
        let mut mem = Memory::new();
        let s = t.config.stride_unroll as u64;
        let p = t.config.portion_unroll as u64;
        let n = t.order.len();
        let trips: Vec<u64> = t
            .order
            .iter()
            .map(|&l| {
                let e = spec.loops[l].extent;
                if l == t.stride_loop {
                    e / s
                } else if l == t.vector_loop {
                    e / (VEC_ELEMS * p)
                } else {
                    e
                }
            })
            .collect();
        if trips.iter().any(|&e| e == 0) {
            return mem;
        }
        let mut counters = vec![0u64; n];
        let mut vals = vec![0u64; spec.loops.len()];
        loop {
            for (pos, &l) in t.order.iter().enumerate() {
                vals[l] = if l == t.stride_loop {
                    counters[pos] * s
                } else if l == t.vector_loop {
                    counters[pos] * VEC_ELEMS * p
                } else {
                    counters[pos]
                };
            }
            let (bs, bv) = (vals[t.stride_loop], vals[t.vector_loop]);
            for k in 0..s {
                for q in 0..p {
                    for e in 0..VEC_ELEMS {
                        vals[t.stride_loop] = bs + k;
                        vals[t.vector_loop] = bv + q * VEC_ELEMS + e;
                        body(spec, &pure, &mut mem, &vals);
                    }
                }
            }
            let mut pos = n;
            loop {
                if pos == 0 {
                    return mem;
                }
                pos -= 1;
                counters[pos] += 1;
                if counters[pos] < trips[pos] {
                    break;
                }
                counters[pos] = 0;
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::kernels::spec::{Array, ArrayAccess, IndexExpr, LoopVar};
        use crate::transform::{transform, StridingConfig};

        fn small_mxv() -> KernelSpec {
            let mut k = KernelSpec {
                name: "mxv".into(),
                loops: vec![LoopVar::new("i", 32), LoopVar::new("j", 64)],
                arrays: vec![
                    Array::new("A", &[32, 64], 4),
                    Array::new("x", &[64], 4),
                    Array::new("y", &[32], 4),
                ],
                accesses: vec![
                    ArrayAccess::new(
                        0,
                        vec![IndexExpr::var(0), IndexExpr::var(1)],
                        AccessMode::Read,
                    ),
                    ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
                    ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
                ],
                loop_carried_dep: false,
            };
            k.layout();
            k
        }

        #[test]
        fn transformed_matches_source_for_all_family_strides() {
            let k = small_mxv();
            let want = execute_source(&k);
            assert!(!want.is_empty());
            for s in [1u32, 2, 4, 8] {
                let t = transform(&k, StridingConfig::new(s, 1)).unwrap();
                assert_eq!(execute_transformed(&t), want, "S={s} diverged");
            }
        }

        #[test]
        fn dropped_iteration_changes_memory() {
            // Sensitivity: shrinking the domain must not go unnoticed.
            let k = small_mxv();
            let mut smaller = k.clone();
            smaller.loops[0].extent -= 1;
            assert_ne!(execute_source(&k), execute_source(&smaller));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blas_refs_attached_to_blas_kernels() {
        let refs = Reference::for_kernel("mxv");
        assert!(refs.contains(&Reference::Mkl));
        assert!(refs.contains(&Reference::OpenBlas));
        assert!(!refs.contains(&Reference::OpenCv));
    }

    #[test]
    fn stencil_refs_attached_to_stencils() {
        let refs = Reference::for_kernel("conv");
        assert!(refs.contains(&Reference::OpenCv));
        assert!(refs.contains(&Reference::HalideAdams));
        assert!(!refs.contains(&Reference::Mkl));
        let refs = Reference::for_kernel("jacobi2d");
        assert!(refs.contains(&Reference::HalideLi));
        assert!(!refs.contains(&Reference::OpenCv), "paper only compares conv to OpenCV");
    }

    #[test]
    fn every_kernel_gets_compiler_baselines() {
        for k in ["mxv", "conv", "jacobi2d", "bicg", "gemversum"] {
            let refs = Reference::for_kernel(k);
            assert!(refs.contains(&Reference::Clang));
            assert!(refs.contains(&Reference::Polly));
            assert!(refs.contains(&Reference::NoUnroll));
            assert!(refs.contains(&Reference::BestSingleStrided));
        }
    }

    #[test]
    fn reference_schedules_are_at_most_two_strides() {
        // No reference implementation multi-strides beyond OpenBLAS's
        // two-row kernels — that is the paper's point.
        for r in [
            Reference::Clang,
            Reference::Polly,
            Reference::Mkl,
            Reference::OpenBlas,
            Reference::HalideMullapudi,
            Reference::HalideAdams,
            Reference::HalideLi,
            Reference::OpenCv,
        ] {
            assert!(r.schedule().stride_unroll <= 2, "{:?}", r);
        }
    }

    #[test]
    fn extended_kernels_get_reference_classes() {
        assert!(Reference::for_kernel("3mm").contains(&Reference::Mkl));
        assert!(Reference::for_kernel("atax").contains(&Reference::OpenBlas));
        assert!(Reference::for_kernel("fdtd2d").contains(&Reference::HalideAdams));
        assert!(!Reference::for_kernel("fdtd2d").contains(&Reference::OpenCv));
        let t = Reference::for_kernel("triad");
        assert!(t.contains(&Reference::Clang) && !t.contains(&Reference::Mkl));
    }

    #[test]
    fn scalar_fallbacks_match_paper_observations() {
        assert!(Reference::Polly.scalar_on("bicg"));
        assert!(Reference::Polly.scalar_on("mxv"));
        assert!(!Reference::Polly.scalar_on("conv"));
        assert!(Reference::Clang.scalar_on("mxv"));
        assert!(!Reference::Mkl.scalar_on("mxv"));
    }
}
