//! Compute-kernel specifications.
//!
//! * [`spec`] — a small affine loop-nest IR: loop variables, arrays, and
//!   affine array accesses. The multi-striding transformation
//!   ([`crate::transform`]) operates on this IR exactly as §5 of the paper
//!   describes (critical-access selection, interchange, vectorization,
//!   portion/stride unrolling).
//! * [`library`] — the kernel universe: the six surveyed kernels of Table 1
//!   (plus gemver's four parts and the init/writeback micro-kernels) and an
//!   extended PolyBench-style family (3mm, atax, fdtd2d, jacobi1d,
//!   stridedcopy, triad), all expressed in the IR and lowered through the
//!   same generic transform.
//! * [`micro`] — the §4 micro-benchmarks (pure load/store/copy loops with a
//!   fixed 32-slot unroll budget) that Figures 2–5 are built from.
//! * [`reference`] — access-pattern models of the state-of-the-art
//!   implementations Figure 7 compares against (CLang, Polly, MKL,
//!   OpenBLAS, Halide, OpenCV). These are *models* of each library's
//!   documented schedule, not the vendor binaries — see DESIGN.md §2.

pub mod library;
pub mod micro;
pub mod reference;
pub mod spec;

pub use library::{all_kernels, extended_kernels, kernel_by_name, paper_kernels, PaperKernel};
pub use micro::{MicroBench, MicroOp};
pub use reference::Reference;
pub use spec::{Array, ArrayAccess, AccessMode, IndexExpr, KernelSpec, LoopVar};
