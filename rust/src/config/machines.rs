//! Machine presets reproducing Table 2 of the paper.
//!
//! Latency/bandwidth numbers derive from the table's documented figures
//! (base frequency, peak bandwidth, cache geometry) plus standard published
//! values for the respective cores; the DRAM service rate is set so the
//! modeled bandwidth roofline equals the paper's measured "Bandwidth"
//! row (single-core loaded bandwidth). See EXPERIMENTS.md for the
//! calibration log.

use crate::mem::{CacheConfig, DramConfig, Replacement, TlbConfig, WriteCombineConfig};
use crate::prefetch::PrefetchConfig;

/// Identifier for the three surveyed micro-architectures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MachinePreset {
    CoffeeLake,
    CascadeLake,
    Zen2,
}

impl MachinePreset {
    pub fn all() -> [MachinePreset; 3] {
        [Self::CoffeeLake, Self::CascadeLake, Self::Zen2]
    }

    pub fn config(self) -> MachineConfig {
        match self {
            Self::CoffeeLake => coffee_lake(),
            Self::CascadeLake => cascade_lake(),
            Self::Zen2 => zen2(),
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "coffeelake" | "coffee-lake" | "coffee_lake" | "i7-8700" => Some(Self::CoffeeLake),
            "cascadelake" | "cascade-lake" | "cascade_lake" | "4214r" => Some(Self::CascadeLake),
            "zen2" | "zen-2" | "epyc" | "7402p" => Some(Self::Zen2),
            _ => None,
        }
    }

    /// Canonical CLI spelling of the preset (the one `--machine` help
    /// advertises; [`Self::from_name`] accepts aliases too).
    pub fn cli_name(self) -> &'static str {
        match self {
            Self::CoffeeLake => "coffee-lake",
            Self::CascadeLake => "cascade-lake",
            Self::Zen2 => "zen2",
        }
    }

    /// [`Self::from_name`] with a CLI-grade error: an unknown name lists
    /// the registered presets (names from [`Self::all`]) instead of
    /// leaving the user to guess — the same policy as the unknown
    /// `--kernel` listing ([`crate::kernels::library::ensure_known_kernel`]).
    pub fn from_name_or_listing(name: &str) -> crate::Result<Self> {
        if let Some(p) = Self::from_name(name) {
            return Ok(p);
        }
        let mut listing = String::new();
        for p in Self::all() {
            let m = p.config();
            listing.push_str(&format!(
                "\n  {:<13} {} {} ({})",
                p.cli_name(),
                m.vendor,
                m.model,
                m.name
            ));
        }
        Err(crate::format_err!(
            "unknown machine {name}; the registered machine presets are:{listing}"
        ))
    }
}

/// Full description of one simulated machine (Table 2 row + model knobs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineConfig {
    pub name: &'static str,
    pub vendor: &'static str,
    pub model: &'static str,
    /// Locked core frequency in GHz (the paper locks 3.2 GHz on Coffee Lake).
    pub freq_ghz: f64,
    /// Paper-reported single-core bandwidth in GiB/s (roofline target).
    pub bandwidth_gib: f64,
    pub mem_channels: u32,
    pub ram_gib: u32,
    pub max_fma_gflops: f64,

    pub l1: CacheConfig,
    pub l2: CacheConfig,
    pub l3: CacheConfig,
    /// Load-to-use latencies in cycles.
    pub l1_lat: u64,
    pub l2_lat: u64,
    pub l3_lat: u64,

    pub dram: DramConfig,
    pub tlb: TlbConfig,
    pub wc: WriteCombineConfig,
    pub prefetch: PrefetchConfig,

    /// Line-fill buffers: maximum outstanding demand misses.
    pub lfb_entries: u32,
    /// Out-of-order window measured in memory accesses (ROB depth divided by
    /// the ~uops between memory ops in these kernels).
    pub window_accesses: u32,
    /// Vector memory operations issued per cycle (2 load ports on all three).
    pub issue_per_cycle: u32,
    /// Architectural SIMD registers available to the kernel generator
    /// (16 ymm for AVX2; the transform's feasibility check uses this).
    pub simd_registers: u32,
}

impl MachineConfig {
    /// Cycles per second.
    pub fn freq_hz(&self) -> f64 {
        self.freq_ghz * 1e9
    }

    /// Convert a cycle count + byte count into GiB/s on this machine.
    pub fn gib_per_s(&self, bytes: u64, cycles: u64) -> f64 {
        if cycles == 0 {
            return 0.0;
        }
        let secs = cycles as f64 / self.freq_hz();
        bytes as f64 / (1u64 << 30) as f64 / secs
    }

    /// Peak modeled DRAM bandwidth in GiB/s (64 B per service slot).
    pub fn model_peak_gib(&self) -> f64 {
        64.0 / self.dram.service_cycles as f64 * self.freq_hz() / (1u64 << 30) as f64
    }
}

/// Intel Core i7-8700 (Coffee Lake) — the paper's analysis platform (§4).
pub fn coffee_lake() -> MachineConfig {
    MachineConfig {
        name: "Coffee Lake",
        vendor: "Intel",
        model: "i7-8700",
        freq_ghz: 3.2,
        bandwidth_gib: 19.87,
        mem_channels: 2,
        ram_gib: 16,
        max_fma_gflops: 147.2,
        l1: CacheConfig::new(32 * 1024, 8, Replacement::Lru),
        l2: CacheConfig::new(256 * 1024, 4, Replacement::Lru),
        l3: CacheConfig::new(12 * 1024 * 1024, 16, Replacement::TreePlru),
        l1_lat: 4,
        l2_lat: 12,
        l3_lat: 42,
        dram: DramConfig {
            // 64 B / 10 cyc @ 3.2 GHz = 19.07 GiB/s read roofline
            // (paper: 19.87); writes pay turnaround (≈55% of read BW).
            service_cycles: 10,
            write_service_cycles: 18,
            row_hit_cycles: 200,
            row_miss_cycles: 300,
            banks: 16,
            row_bytes: 8192,
            partial_write_penalty: 6,
        },
        tlb: TlbConfig::default(),
        wc: WriteCombineConfig { entries: 10 },
        prefetch: PrefetchConfig::default(),
        lfb_entries: 8,
        window_accesses: 36,
        issue_per_cycle: 2,
        simd_registers: 16,
    }
}

/// Intel Xeon Silver 4214R (Cascade Lake).
pub fn cascade_lake() -> MachineConfig {
    MachineConfig {
        name: "Cascade Lake",
        vendor: "Intel",
        model: "Xeon Silver 4214R",
        freq_ghz: 2.4,
        bandwidth_gib: 17.88,
        mem_channels: 6,
        ram_gib: 256,
        max_fma_gflops: 112.0,
        l1: CacheConfig::new(32 * 1024, 8, Replacement::Lru),
        l2: CacheConfig::new(1024 * 1024, 16, Replacement::Lru),
        l3: CacheConfig::new(16 * 1024 * 1024 + 512 * 1024, 11, Replacement::TreePlru),
        l1_lat: 4,
        l2_lat: 14,
        l3_lat: 50,
        dram: DramConfig {
            // 64 B / 8 cyc @ 2.4 GHz = 17.88 GiB/s read roofline.
            service_cycles: 8,
            write_service_cycles: 14,
            row_hit_cycles: 220,
            row_miss_cycles: 330,
            banks: 24,
            row_bytes: 8192,
            partial_write_penalty: 6,
        },
        tlb: TlbConfig::default(),
        wc: WriteCombineConfig { entries: 10 },
        prefetch: PrefetchConfig::default(),
        lfb_entries: 8,
        window_accesses: 36,
        issue_per_cycle: 2,
        simd_registers: 16,
    }
}

/// AMD EPYC 7402P (Zen 2).
pub fn zen2() -> MachineConfig {
    let mut prefetch = PrefetchConfig::default();
    // Zen 2's L2 stream prefetcher is somewhat shallower per stream than
    // Intel's but the L3 is per-CCX; net effect in the paper: same trend,
    // smaller multi-striding margins on several kernels.
    prefetch.streamer.per_stream_outstanding = 10;
    prefetch.streamer.max_distance = 16;
    MachineConfig {
        name: "Zen 2",
        vendor: "AMD",
        model: "EPYC 7402P",
        freq_ghz: 2.8,
        bandwidth_gib: 23.84,
        mem_channels: 8,
        ram_gib: 128,
        max_fma_gflops: 102.4,
        l1: CacheConfig::new(32 * 1024, 8, Replacement::Lru),
        l2: CacheConfig::new(512 * 1024, 8, Replacement::Lru),
        l3: CacheConfig::new(16 * 1024 * 1024, 16, Replacement::TreePlru),
        l1_lat: 4,
        l2_lat: 12,
        l3_lat: 39,
        dram: DramConfig {
            // 64 B / 7 cyc @ 2.8 GHz = 23.87 GiB/s read roofline.
            service_cycles: 7,
            write_service_cycles: 12,
            row_hit_cycles: 230,
            row_miss_cycles: 350,
            banks: 32,
            row_bytes: 8192,
            partial_write_penalty: 6,
        },
        tlb: TlbConfig::default(),
        wc: WriteCombineConfig { entries: 12 },
        prefetch,
        lfb_entries: 10,
        window_accesses: 36,
        issue_per_cycle: 2,
        simd_registers: 16,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table2_geometry() {
        let cl = coffee_lake();
        assert_eq!(cl.l1.size_bytes, 32 * 1024);
        assert_eq!(cl.l1.ways, 8);
        assert_eq!(cl.l2.size_bytes, 256 * 1024);
        assert_eq!(cl.l2.ways, 4);
        assert_eq!(cl.l3.size_bytes, 12 * 1024 * 1024);
        assert_eq!(cl.l3.ways, 16);

        let xl = cascade_lake();
        assert_eq!(xl.l2.size_bytes, 1024 * 1024);
        assert_eq!(xl.l2.ways, 16);

        let z = zen2();
        assert_eq!(z.l2.size_bytes, 512 * 1024);
        assert_eq!(z.l2.ways, 8);
    }

    #[test]
    fn model_roofline_close_to_paper_bandwidth() {
        for m in [coffee_lake(), cascade_lake(), zen2()] {
            let ratio = m.model_peak_gib() / m.bandwidth_gib;
            assert!(
                (0.9..=1.1).contains(&ratio),
                "{}: model roofline {:.2} vs paper {:.2}",
                m.name,
                m.model_peak_gib(),
                m.bandwidth_gib
            );
        }
    }

    #[test]
    fn preset_lookup_by_name() {
        assert_eq!(MachinePreset::from_name("coffee-lake"), Some(MachinePreset::CoffeeLake));
        assert_eq!(MachinePreset::from_name("i7-8700"), Some(MachinePreset::CoffeeLake));
        assert_eq!(MachinePreset::from_name("zen2"), Some(MachinePreset::Zen2));
        assert_eq!(MachinePreset::from_name("m1"), None);
    }

    #[test]
    fn unknown_machine_error_lists_every_preset() {
        // The `--machine` boundary: a typo must come back with the whole
        // registered preset list, not a bare panic.
        let err = MachinePreset::from_name_or_listing("m1").unwrap_err().to_string();
        assert!(err.contains("unknown machine m1"), "{err}");
        for p in MachinePreset::all() {
            assert!(err.contains(p.cli_name()), "listing must include {:?}: {err}", p);
            assert!(err.contains(p.config().model), "listing must include the model: {err}");
        }
        // Known names (canonical and alias) still resolve.
        for p in MachinePreset::all() {
            assert_eq!(MachinePreset::from_name_or_listing(p.cli_name()).unwrap(), p);
        }
        assert_eq!(
            MachinePreset::from_name_or_listing("EPYC").unwrap(),
            MachinePreset::Zen2
        );
    }

    #[test]
    fn gib_conversion() {
        let m = coffee_lake();
        // 3.2e9 cycles = 1 s; 2^30 bytes = 1 GiB.
        let g = m.gib_per_s(1 << 30, 3_200_000_000);
        assert!((g - 1.0).abs() < 1e-9);
    }
}
