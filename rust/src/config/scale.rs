//! Data-size scaling between the paper's testbed and the simulator.
//!
//! The paper streams 1.9–2.0 GiB in the micro-benchmarks and 2–4 GiB per
//! kernel. Simulating every 32-byte access of those footprints for hundreds
//! of configurations is wasteful: only the footprint *relative to the L3*
//! and the power-of-two aliasing property matter (§4.5). The default scale
//! keeps both: 60 MiB (non-power-of-two) and 64 MiB (exact power of two)
//! against the modeled 12 MiB L3 — the same ≥5× ratio the paper uses.

/// Byte sizes used by the experiment drivers.
#[derive(Debug, Clone, Copy)]
pub struct ScaleConfig {
    /// Micro-benchmark array, non-power-of-two (paper: ~1.9 GiB).
    pub micro_bytes: u64,
    /// Micro-benchmark array, exact power-of-two (paper: 2.0 GiB).
    pub micro_pow2_bytes: u64,
    /// Per-kernel data budget for the Figure 6/7 experiments
    /// (paper: 2–4 GiB).
    pub kernel_bytes: u64,
    /// Measurement repetitions (paper: median of 5 runs × 5 executions;
    /// the simulator is deterministic, so 1 run per warmup+measure pair
    /// suffices — kept configurable for the native mode).
    pub repetitions: u32,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        Self {
            // 32 × odd × 64 B ≈ 59.6 MiB: for every stride count n | 32 the
            // per-stride span is an odd-ish line count, so concurrent
            // strides spread across cache sets — the property the paper's
            // "approximately 1.9 GiB" array has and the exact-2-GiB array
            // of §4.5 deliberately lacks.
            micro_bytes: 32 * 30517 * 64,
            micro_pow2_bytes: 64 * 1024 * 1024,
            kernel_bytes: 48 * 1024 * 1024,
            repetitions: 1,
        }
    }
}

impl ScaleConfig {
    /// A fast scale for unit tests and smoke runs (still ≥2× the modeled
    /// L3 so misses dominate).
    pub fn smoke() -> Self {
        Self {
            micro_bytes: 32 * 12207 * 64, // ≈ 23.8 MiB, same odd-span property
            micro_pow2_bytes: 32 * 1024 * 1024,
            kernel_bytes: 24 * 1024 * 1024,
            repetitions: 1,
        }
    }

    /// Scale factor relative to the paper's 1.9 GiB micro array (for
    /// reporting).
    pub fn micro_scale_factor(&self) -> f64 {
        (1.9 * (1u64 << 30) as f64) / self.micro_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_preserves_pow2_property() {
        let s = ScaleConfig::default();
        assert!(s.micro_pow2_bytes.is_power_of_two());
        assert!(!s.micro_bytes.is_power_of_two());
    }

    #[test]
    fn default_is_beyond_l3() {
        let s = ScaleConfig::default();
        let l3 = 12 * 1024 * 1024;
        assert!(s.micro_bytes >= 4 * l3);
        assert!(s.micro_pow2_bytes >= 5 * l3);
    }
}
