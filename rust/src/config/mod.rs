//! Experiment configuration: machine presets (Table 2 of the paper), data
//! scaling, and a TOML-subset loader for user-supplied experiment files.

pub mod machines;
pub mod scale;
pub mod toml_file;

pub use machines::{cascade_lake, coffee_lake, zen2, MachineConfig, MachinePreset};
pub use scale::ScaleConfig;
pub use toml_file::ExperimentFile;
