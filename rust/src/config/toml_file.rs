//! Minimal TOML-subset parser for experiment files.
//!
//! No third-party crates are available offline, so the config system ships
//! its own parser covering the subset experiment files need: `[section]`
//! headers, `key = value` with string / integer / float / boolean / array
//! values, `#` comments and blank lines.
//!
//! ```toml
//! [experiment]
//! machine = "coffee-lake"
//! strides = [1, 2, 4, 8, 16, 32]
//! prefetch = true
//! array_mib = 60
//! ```

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_int_array(&self) -> Option<Vec<i64>> {
        match self {
            Value::Array(vs) => vs.iter().map(|v| v.as_int()).collect(),
            _ => None,
        }
    }
}

/// A parsed experiment file: `section -> key -> value`.
#[derive(Debug, Default, Clone)]
pub struct ExperimentFile {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// Parse error with a line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl ExperimentFile {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut out = ExperimentFile::default();
        let mut section = String::new();
        out.sections.entry(section.clone()).or_default();

        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| ParseError {
                line: ln + 1,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let value = parse_value(val.trim()).map_err(|msg| ParseError { line: ln + 1, msg })?;
            out.sections
                .get_mut(&section)
                .expect("section exists")
                .insert(key.trim().to_string(), value);
        }
        Ok(out)
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let body = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let body = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if body.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = split_top_level(body)
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split an array body on commas that are not nested in sub-arrays/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let f = ExperimentFile::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n[s]\ne = false\n",
        )
        .unwrap();
        assert_eq!(f.get("", "a").unwrap().as_int(), Some(1));
        assert_eq!(f.get("", "b").unwrap().as_float(), Some(2.5));
        assert_eq!(f.get("", "c").unwrap().as_str(), Some("hi"));
        assert_eq!(f.get("", "d").unwrap().as_bool(), Some(true));
        assert_eq!(f.get("s", "e").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_arrays() {
        let f = ExperimentFile::parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nzs = []\n").unwrap();
        assert_eq!(f.get("", "xs").unwrap().as_int_array(), Some(vec![1, 2, 3]));
        match f.get("", "ys").unwrap() {
            Value::Array(vs) => assert_eq!(vs.len(), 2),
            v => panic!("{v:?}"),
        }
        assert_eq!(f.get("", "zs").unwrap().as_int_array(), Some(vec![]));
    }

    #[test]
    fn comments_and_underscores() {
        let f = ExperimentFile::parse("# header\nn = 1_000_000 # inline\ns = \"a # b\"\n").unwrap();
        assert_eq!(f.get("", "n").unwrap().as_int(), Some(1_000_000));
        assert_eq!(f.get("", "s").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = ExperimentFile::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = ExperimentFile::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn int_array_of_mixed_fails_gracefully() {
        let f = ExperimentFile::parse("xs = [1, \"two\"]\n").unwrap();
        assert_eq!(f.get("", "xs").unwrap().as_int_array(), None);
    }

    #[test]
    fn nested_arrays() {
        let f = ExperimentFile::parse("m = [[1, 2], [3, 4]]\n").unwrap();
        match f.get("", "m").unwrap() {
            Value::Array(rows) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0].as_int_array(), Some(vec![1, 2]));
            }
            v => panic!("{v:?}"),
        }
    }
}
