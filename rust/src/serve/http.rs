//! Hand-rolled HTTP/1.1 plumbing for `repro serve`.
//!
//! The crate is deliberately dependency-free, so this is a minimal but
//! correct subset of RFC 9112 in the style of `config/toml_file.rs`:
//! enough to parse a request line plus headers, percent-decode a query
//! string, and write framed `Content-Length` responses over keep-alive
//! connections. Anything outside the subset degrades to a clean error
//! response, never a hang or a panic:
//!
//! * header blocks are capped at 8 KiB (`431` beyond that);
//! * only `GET` is routed (`405` otherwise — the daemon is read-only);
//! * sockets carry a read timeout so an idle client cannot pin a
//!   thread forever;
//! * malformed request lines close the connection with `400`.
//!
//! The accept loop is thread-per-connection (plan responses are a few
//! hundred bytes; connection counts in the benches top out far below
//! thread-pool territory) and stops on a shared [`ServerControl`]:
//! either an explicit `request_stop` or an optional request budget
//! (`--max-requests`), which is what makes the CI smoke job
//! deterministic without signal handling. Shutdown wakes the blocking
//! `accept` by dialing the listener once, then drains in-flight
//! connections before returning.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::{format_err, Result};

/// Cap on the request line + header block, per request.
const MAX_HEADER_BYTES: usize = 8 * 1024;
/// Idle-client guard on every connection.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// A parsed request: method, decoded path, decoded query pairs.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Vec<(String, String)>,
    /// Client asked to drop the connection after this response.
    pub close: bool,
}

impl Request {
    /// Last value for `name` (duplicate params: last one wins).
    pub fn param(&self, name: &str) -> Option<&str> {
        self.query.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// A response ready to frame: status, media type, body bytes.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "text/plain; charset=utf-8", body: body.into().into_bytes() }
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Self {
        Self { status, content_type: "application/octet-stream", body }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            431 => "Request Header Fields Too Large",
            500 => "Internal Server Error",
            _ => "Response",
        }
    }

    fn write_to(&self, stream: &mut TcpStream, close: bool) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Decode `%XX` escapes and `+`-as-space; invalid escapes pass through
/// verbatim (the service layer rejects values it cannot use anyway).
pub fn percent_decode(s: &str) -> String {
    fn hex(b: u8) -> Option<u8> {
        match b {
            b'0'..=b'9' => Some(b - b'0'),
            b'a'..=b'f' => Some(b - b'a' + 10),
            b'A'..=b'F' => Some(b - b'A' + 10),
            _ => None,
        }
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' if i + 2 < bytes.len() => match (hex(bytes[i + 1]), hex(bytes[i + 2])) {
                (Some(hi), Some(lo)) => {
                    out.push((hi << 4) | lo);
                    i += 3;
                }
                _ => {
                    out.push(b'%');
                    i += 1;
                }
            },
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Split `path?query` into the decoded path and decoded key=value pairs.
fn parse_target(target: &str) -> (String, Vec<(String, String)>) {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let pairs = query
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect();
    (percent_decode(path), pairs)
}

/// Read one request's head off the wire. `Ok(None)` means the client
/// closed cleanly between requests (normal keep-alive end).
fn read_request(reader: &mut BufReader<&TcpStream>) -> Result<Option<Request>> {
    let mut head = String::new();
    loop {
        let before = head.len();
        let n = reader
            .read_line(&mut head)
            .map_err(|e| format_err!("reading request head: {e}"))?;
        if n == 0 {
            if before == 0 {
                return Ok(None);
            }
            return Err(format_err!("connection closed mid-request"));
        }
        if head.len() > MAX_HEADER_BYTES {
            return Err(format_err!("request head exceeds {MAX_HEADER_BYTES} bytes"));
        }
        // A lone CRLF terminates the head.
        if head.ends_with("\r\n\r\n") || head == "\r\n" || head.ends_with("\n\n") {
            break;
        }
    }
    let mut lines = head.lines();
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if v.starts_with("HTTP/1.") => (m, t, v),
        _ => return Err(format_err!("malformed request line: {request_line:?}")),
    };
    let mut close = version == "HTTP/1.0";
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("connection") && value.trim().eq_ignore_ascii_case("close")
            {
                close = true;
            }
        }
    }
    let (path, query) = parse_target(target);
    Ok(Some(Request { method: method.to_string(), path, query, close }))
}

/// Shared shutdown/budget state between the accept loop, connection
/// threads, and whoever owns the daemon.
pub struct ServerControl {
    shutdown: AtomicBool,
    served: AtomicU64,
    max_requests: Option<u64>,
    port: AtomicU64,
}

impl ServerControl {
    pub fn new(max_requests: Option<u64>) -> Arc<Self> {
        Arc::new(Self {
            shutdown: AtomicBool::new(false),
            served: AtomicU64::new(0),
            max_requests,
            port: AtomicU64::new(0),
        })
    }

    pub fn requests_served(&self) -> u64 {
        self.served.load(Ordering::SeqCst)
    }

    pub fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Ask the accept loop to stop, waking it if it is parked.
    pub fn request_stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let port = self.port.load(Ordering::SeqCst) as u16;
        if port != 0 {
            // Accept is blocking; a throwaway dial unparks it so it can
            // observe the flag. Failure is fine — the loop also checks
            // the flag on every natural wakeup.
            let _ = TcpStream::connect(("127.0.0.1", port));
        }
    }

    /// Count one finished request; returns true when this request
    /// exhausted the budget (that request is still answered in full).
    fn count_request(&self) -> bool {
        let n = self.served.fetch_add(1, Ordering::SeqCst) + 1;
        matches!(self.max_requests, Some(max) if n >= max)
    }
}

/// A bound listener plus its accept loop.
pub struct HttpServer {
    listener: TcpListener,
    port: u16,
}

impl HttpServer {
    /// Bind on localhost; port 0 picks a free port (tests, benches).
    pub fn bind(port: u16) -> Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| format_err!("binding 127.0.0.1:{port}: {e}"))?;
        let port = listener.local_addr().map_err(|e| format_err!("local_addr: {e}"))?.port();
        Ok(Self { listener, port })
    }

    pub fn port(&self) -> u16 {
        self.port
    }

    /// Accept until `ctl` says stop; one thread per connection, drained
    /// before returning. The handler must answer every request (the
    /// wrapper maps a handler panic to a closed connection, not a
    /// daemon crash).
    pub fn serve<H>(&self, handler: Arc<H>, ctl: Arc<ServerControl>) -> Result<()>
    where
        H: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        ctl.port.store(self.port as u64, Ordering::SeqCst);
        let active = Arc::new(AtomicUsize::new(0));
        for conn in self.listener.incoming() {
            if ctl.stopping() {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let (handler, ctl, active) = (handler.clone(), ctl.clone(), active.clone());
            active.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &*handler, &ctl);
                active.fetch_sub(1, Ordering::SeqCst);
            });
        }
        // Drain in-flight connections (bounded by the read timeout).
        while active.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }
}

fn handle_connection<H>(stream: TcpStream, handler: &H, ctl: &ServerControl) -> Result<()>
where
    H: Fn(&Request) -> Response,
{
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    let mut reader = BufReader::new(&stream);
    let mut writer = stream.try_clone().map_err(|e| format_err!("cloning stream: {e}"))?;
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()), // clean keep-alive close
            Err(e) => {
                let msg = e.to_string();
                let status = if msg.contains("exceeds") { 431 } else { 400 };
                let _ = Response::text(status, format!("{msg}\n")).write_to(&mut writer, true);
                return Ok(());
            }
        };
        // The head is all we frame; a GET body is not expected, and
        // anything else is refused before a body could matter.
        let response = if req.method == "GET" {
            handler(&req)
        } else {
            Response::text(405, "only GET is served\n")
        };
        let exhausted = ctl.count_request();
        let close = req.close || exhausted || ctl.stopping();
        response.write_to(&mut writer, close).map_err(|e| format_err!("writing response: {e}"))?;
        if exhausted {
            ctl.request_stop();
        }
        if close {
            return Ok(());
        }
    }
}

/// Minimal scripted client for tests and the bench load generator:
/// one keep-alive connection, sequential GETs.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(port: u16) -> Result<Self> {
        let stream = TcpStream::connect(("127.0.0.1", port))
            .map_err(|e| format_err!("connecting to 127.0.0.1:{port}: {e}"))?;
        stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
        Ok(Self { stream })
    }

    /// Issue `GET <target>`; returns (status, body bytes).
    pub fn get(&mut self, target: &str) -> Result<(u16, Vec<u8>)> {
        let req = format!("GET {target} HTTP/1.1\r\nHost: 127.0.0.1\r\n\r\n");
        self.stream.write_all(req.as_bytes()).map_err(|e| format_err!("sending request: {e}"))?;
        let mut reader = BufReader::new(&self.stream);
        let mut status_line = String::new();
        reader.read_line(&mut status_line).map_err(|e| format_err!("reading status: {e}"))?;
        let status: u16 = status_line
            .split_ascii_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format_err!("malformed status line: {status_line:?}"))?;
        let mut content_length = 0usize;
        loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).map_err(|e| format_err!("reading header: {e}"))?;
            if n == 0 || line == "\r\n" || line == "\n" {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value
                        .trim()
                        .parse()
                        .map_err(|e| format_err!("bad content-length {value:?}: {e}"))?;
                }
            }
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body).map_err(|e| format_err!("reading body: {e}"))?;
        Ok((status, body))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percent_decoding_handles_escapes_plus_and_junk() {
        assert_eq!(percent_decode("coffee-lake"), "coffee-lake");
        assert_eq!(percent_decode("coffee%2Dlake"), "coffee-lake");
        assert_eq!(percent_decode("a+b%20c"), "a b c");
        assert_eq!(percent_decode("bad%zzend"), "bad%zzend", "invalid escape passes through");
        assert_eq!(percent_decode("cut%2"), "cut%2", "truncated escape passes through");
    }

    #[test]
    fn target_parsing_splits_path_and_params() {
        let (path, q) = parse_target("/plan?kernel=mxv&machine=coffee%2Dlake&flag");
        assert_eq!(path, "/plan");
        assert_eq!(q[0], ("kernel".to_string(), "mxv".to_string()));
        assert_eq!(q[1], ("machine".to_string(), "coffee-lake".to_string()));
        assert_eq!(q[2], ("flag".to_string(), String::new()));
        let req = Request { method: "GET".into(), path, query: q, close: false };
        assert_eq!(req.param("kernel"), Some("mxv"));
        assert_eq!(req.param("absent"), None);
    }

    #[test]
    fn duplicate_params_last_one_wins() {
        let (_, q) = parse_target("/plan?budget=1&budget=2");
        let req = Request { method: "GET".into(), path: "/plan".into(), query: q, close: false };
        assert_eq!(req.param("budget"), Some("2"));
    }

    #[test]
    fn round_trip_over_a_real_socket() {
        let server = HttpServer::bind(0).unwrap();
        let port = server.port();
        let ctl = ServerControl::new(Some(3));
        let handler = Arc::new(|req: &Request| {
            Response::text(200, format!("path={} kernel={:?}\n", req.path, req.param("kernel")))
        });
        let srv_ctl = ctl.clone();
        let join = std::thread::spawn(move || server.serve(handler, srv_ctl));

        let mut client = Client::connect(port).unwrap();
        // Two requests over one keep-alive connection.
        let (status, body) = client.get("/plan?kernel=mxv").unwrap();
        assert_eq!(status, 200);
        assert_eq!(String::from_utf8_lossy(&body), "path=/plan kernel=Some(\"mxv\")\n");
        let (status, _) = client.get("/healthz").unwrap();
        assert_eq!(status, 200);
        // Drop the idle connection so the drain loop need not wait out
        // its read timeout.
        drop(client);
        // Third request exhausts the budget and stops the daemon.
        let mut second = Client::connect(port).unwrap();
        let (status, _) = second.get("/plan").unwrap();
        assert_eq!(status, 200);
        join.join().unwrap().unwrap();
        assert_eq!(ctl.requests_served(), 3);
    }

    #[test]
    fn non_get_is_405_and_garbage_is_400() {
        let server = HttpServer::bind(0).unwrap();
        let port = server.port();
        let ctl = ServerControl::new(None);
        let handler = Arc::new(|_: &Request| Response::text(200, "ok\n"));
        let srv_ctl = ctl.clone();
        let join = std::thread::spawn(move || server.serve(handler, srv_ctl));

        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream.write_all(b"POST /plan HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = String::new();
        BufReader::new(&stream).read_line(&mut buf).unwrap();
        assert!(buf.contains("405"), "got: {buf}");

        let mut bad = TcpStream::connect(("127.0.0.1", port)).unwrap();
        bad.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        let mut buf = String::new();
        BufReader::new(&bad).read_line(&mut buf).unwrap();
        assert!(buf.contains("400"), "got: {buf}");

        // Free the parked keep-alive thread before stopping: the drain
        // loop waits for active connections.
        drop(stream);
        drop(bad);
        ctl.request_stop();
        join.join().unwrap().unwrap();
    }
}
