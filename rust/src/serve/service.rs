//! The plan-serving service: request grammar, resolution order, miss
//! policy, and the daemon's counters.
//!
//! One [`PlanService`] answers the whole endpoint surface:
//!
//! * `GET /plan?kernel=..&machine=..&budget=..&prefetch=..` — the exact
//!   serialized [`TunedPlan`] bytes (the same bytes `repro tune` writes
//!   to `<plans>/<key>.plan`; the plan format's bit-identical
//!   serialize→parse→serialize round trip is what makes "served bytes
//!   == tuner bytes" a checkable contract, and `tests/serve_http.rs`
//!   checks it);
//! * `GET /plans?kernels=a,b,c&machine=..&budget=..` — the batched
//!   variant: one round trip resolves a comma-separated kernel list,
//!   answering one status line per kernel (`status=ok source=..` or
//!   `status=error code=..`) — per-kernel failures never fail the batch;
//! * `GET /counters?…` — the same plan rendered as human-readable
//!   predicted counters (`key=value` lines);
//! * `GET /stats` — the live `[serve]` summary line;
//! * `GET /metrics` — Prometheus text exposition of the obs registry
//!   (serve + result-store counters folded in at scrape time, plus the
//!   per-endpoint `serve_<endpoint>_request_us` latency histograms
//!   every request records);
//! * `GET /healthz` — liveness probe; answers `degraded` (still 200)
//!   when the result store has dropped to memory-only after repeated
//!   disk failures, so fleet probes can see the condition without
//!   declaring the daemon dead.
//!
//! Resolution order for a plan request is pool → disk → miss policy:
//! the bounded [`BufferPool`] first, then a [`PlanCache`] load whose
//! identity triple (`spec_hash`, `machine_fingerprint`, `budget_class`)
//! is validated exactly the way [`Tuner::tune_on`] validates it — a
//! renamed or stale plan file is a miss here too, never a wrong serve.
//! What a miss means is the `--on-miss` knob: [`MissPolicy::NotFound`]
//! answers 404 (pure read replica), [`MissPolicy::Tune`] runs the
//! tuner's search on demand with **single-flight dedup** — concurrent
//! requests for the same key park on a condvar while one flight
//! searches, then re-probe the pool, so a thundering herd costs one
//! search (pinned by `tests/serve_http.rs`).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::http::{Request, Response};
use super::pool::{BufferPool, PoolStats};
use super::replacer::Policy;
use crate::config::machines::{MachineConfig, MachinePreset};
use crate::coordinator::experiments::EngineCache;
use crate::exec::ResultStore;
use crate::kernels::library::kernel_by_name;
use crate::tune::plan::{budget_class, fnv64, machine_fingerprint, spec_hash, TunedPlan};
use crate::tune::{PlanCache, Tuner};
use crate::{format_err, Result};

/// What a full miss (pool and disk) resolves to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissPolicy {
    /// Pure read replica: answer 404, never simulate.
    NotFound,
    /// Tune on demand through the [`Tuner`], single-flighted per key.
    Tune,
}

impl MissPolicy {
    pub fn cli_name(self) -> &'static str {
        match self {
            Self::NotFound => "404",
            Self::Tune => "tune",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "404" => Ok(Self::NotFound),
            "tune" => Ok(Self::Tune),
            other => {
                Err(format_err!("unknown miss policy {other:?} (expected one of: 404, tune)"))
            }
        }
    }
}

/// Where a served plan came from (per-request provenance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    Pool,
    Disk,
    Tuned,
}

/// A successfully resolved plan: the exact bytes plus provenance.
pub struct Served {
    pub bytes: Arc<Vec<u8>>,
    pub source: PlanSource,
}

/// Service-layer failure, carrying the HTTP status it maps to.
#[derive(Debug)]
pub enum ServeError {
    /// Malformed or unresolvable parameters (400).
    BadRequest(String),
    /// Well-formed key with no plan under the active miss policy (404).
    NotFound(String),
    /// The on-demand tune itself failed (500).
    Internal(String),
}

impl ServeError {
    pub fn status(&self) -> u16 {
        match self {
            Self::BadRequest(_) => 400,
            Self::NotFound(_) => 404,
            Self::Internal(_) => 500,
        }
    }

    pub fn message(&self) -> &str {
        match self {
            Self::BadRequest(m) | Self::NotFound(m) | Self::Internal(m) => m,
        }
    }
}

#[derive(Default)]
struct Counters {
    disk_loads: AtomicU64,
    tunes: AtomicU64,
    tune_failures: AtomicU64,
    single_flight_waits: AtomicU64,
    not_found: AtomicU64,
    bad_requests: AtomicU64,
}

/// Snapshot of everything the `[serve]` summary line reports.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    pub pool: PoolStats,
    pub policy: Policy,
    pub on_miss: MissPolicy,
    pub disk_loads: u64,
    pub tunes: u64,
    pub tune_failures: u64,
    pub single_flight_waits: u64,
    pub not_found: u64,
    pub bad_requests: u64,
}

/// The daemon's brain: pool + stores + miss policy + counters. Shared
/// across connection threads by `Arc`; every method takes `&self`.
pub struct PlanService {
    pool: BufferPool,
    plans: PlanCache,
    store: ResultStore,
    on_miss: MissPolicy,
    inflight: Mutex<HashSet<u64>>,
    flight_done: Condvar,
    counters: Counters,
}

/// Pool key for one plan identity. Length-prefixed FNV over the same
/// four coordinates the on-disk cache is keyed by (machine by resolved
/// preset name, budget by class) so equivalent spellings collapse to
/// one entry.
pub fn plan_key(kernel: &str, machine: &str, prefetch: bool, budget_class: u32) -> u64 {
    let mut buf = Vec::with_capacity(kernel.len() + machine.len() + 24);
    buf.extend_from_slice(&(kernel.len() as u64).to_le_bytes());
    buf.extend_from_slice(kernel.as_bytes());
    buf.extend_from_slice(&(machine.len() as u64).to_le_bytes());
    buf.extend_from_slice(machine.as_bytes());
    buf.push(prefetch as u8);
    buf.extend_from_slice(&budget_class.to_le_bytes());
    fnv64(&buf)
}

impl PlanService {
    pub fn new(
        pool_bytes: u64,
        policy: Policy,
        on_miss: MissPolicy,
        plans: PlanCache,
        store: ResultStore,
    ) -> Self {
        Self {
            pool: BufferPool::new(pool_bytes, policy),
            plans,
            store,
            on_miss,
            inflight: Mutex::new(HashSet::new()),
            flight_done: Condvar::new(),
            counters: Counters::default(),
        }
    }

    pub fn on_miss(&self) -> MissPolicy {
        self.on_miss
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            pool: self.pool.stats(),
            policy: self.pool.policy(),
            on_miss: self.on_miss,
            disk_loads: self.counters.disk_loads.load(Ordering::SeqCst),
            tunes: self.counters.tunes.load(Ordering::SeqCst),
            tune_failures: self.counters.tune_failures.load(Ordering::SeqCst),
            single_flight_waits: self.counters.single_flight_waits.load(Ordering::SeqCst),
            not_found: self.counters.not_found.load(Ordering::SeqCst),
            bad_requests: self.counters.bad_requests.load(Ordering::SeqCst),
        }
    }

    /// Resolve a plan identity to its serialized bytes: pool → disk →
    /// miss policy. This is the library entry the HTTP handler, the
    /// bench load generator, and the tests all share.
    pub fn plan_bytes(
        &self,
        kernel: &str,
        machine: &str,
        budget: u64,
        prefetch: bool,
    ) -> std::result::Result<Served, ServeError> {
        let preset = MachinePreset::from_name_or_listing(machine)
            .map_err(|e| ServeError::BadRequest(e.to_string()))?;
        let cfg = preset.config();
        let pk = kernel_by_name(kernel, budget).ok_or_else(|| {
            ServeError::NotFound(format!("unknown kernel {kernel:?} (see `repro universe`)"))
        })?;
        let class = budget_class(budget);
        let key = plan_key(kernel, cfg.name, prefetch, class);
        let want = (spec_hash(&pk.spec), machine_fingerprint(&cfg, prefetch), class);

        loop {
            if let Some(bytes) = self.pool.get(key) {
                return Ok(Served { bytes, source: PlanSource::Pool });
            }
            if let Some(plan) = self.load_valid(kernel, &cfg, prefetch, want) {
                let bytes = Arc::new(plan.serialize().into_bytes());
                self.pool.insert(key, bytes.clone());
                return Ok(Served { bytes, source: PlanSource::Disk });
            }
            match self.on_miss {
                MissPolicy::NotFound => {
                    self.counters.not_found.fetch_add(1, Ordering::SeqCst);
                    return Err(ServeError::NotFound(format!(
                        "no tuned plan for kernel={kernel} machine={} budget_class={class} \
                         prefetch={prefetch} (daemon runs with --on-miss 404; tune it first \
                         or restart with --on-miss tune)",
                        preset.cli_name(),
                    )));
                }
                MissPolicy::Tune => {
                    let mut inflight = self.inflight.lock().unwrap();
                    if inflight.contains(&key) {
                        // Another request is already searching this key:
                        // park, then re-probe pool/disk from the top.
                        self.counters.single_flight_waits.fetch_add(1, Ordering::SeqCst);
                        while inflight.contains(&key) {
                            inflight = self.flight_done.wait(inflight).unwrap();
                        }
                        drop(inflight);
                        continue;
                    }
                    inflight.insert(key);
                    drop(inflight);
                    let tuned = self.tune_now(&cfg, budget, prefetch, kernel);
                    let mut inflight = self.inflight.lock().unwrap();
                    inflight.remove(&key);
                    self.flight_done.notify_all();
                    drop(inflight);
                    match tuned {
                        Ok(plan) => {
                            let bytes = Arc::new(plan.serialize().into_bytes());
                            self.pool.insert(key, bytes.clone());
                            return Ok(Served { bytes, source: PlanSource::Tuned });
                        }
                        Err(e) => {
                            self.counters.tune_failures.fetch_add(1, Ordering::SeqCst);
                            return Err(ServeError::Internal(format!(
                                "tuning {kernel} on demand failed: {e}"
                            )));
                        }
                    }
                }
            }
        }
    }

    /// Disk lookup with the tuner's identity-triple validation; a
    /// stale, unreadable, or corrupt plan is a miss, never a serve.
    fn load_valid(
        &self,
        kernel: &str,
        cfg: &MachineConfig,
        prefetch: bool,
        want: (u64, u64, u32),
    ) -> Option<TunedPlan> {
        match self.plans.load(kernel, cfg.name, prefetch, want.2) {
            Ok(Some(p))
                if p.spec_hash == want.0
                    && p.machine_fingerprint == want.1
                    && p.budget_class == want.2 =>
            {
                self.counters.disk_loads.fetch_add(1, Ordering::SeqCst);
                Some(p)
            }
            Ok(Some(_)) | Ok(None) => None,
            Err(e) => {
                eprintln!("[serve] plan load for {kernel}: {e} — treating as miss");
                None
            }
        }
    }

    /// One on-demand tuning flight. `force=false`: the search re-checks
    /// the disk cache first, so a flight that lost a race — to a
    /// concurrent `repro tune` process, or to a just-finished flight it
    /// narrowly missed waiting on — serves that winner's plan instead
    /// of re-searching. The `tunes` counter therefore counts *searches
    /// actually run*, which is what "a thundering herd runs one search"
    /// promises.
    fn tune_now(
        &self,
        cfg: &MachineConfig,
        budget: u64,
        prefetch: bool,
        kernel: &str,
    ) -> Result<TunedPlan> {
        let tuner = Tuner { prefetch, ..Tuner::new(*cfg, budget) };
        let mut engines = EngineCache::new();
        let out = tuner.tune_on(&self.store, &mut engines, &self.plans, kernel, false)?;
        if !out.cache_hit {
            self.counters.tunes.fetch_add(1, Ordering::SeqCst);
        }
        Ok(out.plan)
    }

    /// HTTP dispatch: routes, parameter grammar, status mapping. Every
    /// request is counted and spanned, and its latency lands in a
    /// per-endpoint log2 histogram (`serve_<endpoint>_request_us`).
    pub fn handle(&self, req: &Request) -> Response {
        let (endpoint, span_name) = match req.path.as_str() {
            "/plan" => ("plan", "serve /plan"),
            "/plans" => ("plans", "serve /plans"),
            "/counters" => ("counters", "serve /counters"),
            "/stats" => ("stats", "serve /stats"),
            "/metrics" => ("metrics", "serve /metrics"),
            "/healthz" => ("healthz", "serve /healthz"),
            _ => ("other", "serve other"),
        };
        // Counted before routing so a /metrics scrape includes itself.
        crate::obs::global().counter_add("serve_http_requests_total", 1);
        let _span = crate::obs::span(span_name);
        let start = std::time::Instant::now();
        let resp = self.route(req);
        crate::obs::global()
            .observe(&format!("serve_{endpoint}_request_us"), start.elapsed().as_micros() as u64);
        resp
    }

    fn route(&self, req: &Request) -> Response {
        match req.path.as_str() {
            "/healthz" => {
                if self.store.is_degraded() {
                    Response::text(
                        200,
                        "degraded: result store is memory-only (persistent tier disabled)\n",
                    )
                } else {
                    Response::text(200, "ok\n")
                }
            }
            "/stats" => {
                let line = crate::report::figures::render_serve_summary(&self.stats());
                Response::text(200, format!("{line}\n"))
            }
            "/metrics" => {
                let reg = crate::obs::global();
                crate::obs::fold_exec_stats(reg, &self.store.stats());
                let snap = crate::obs::fold_serve_stats(reg, &self.stats());
                Response::text(200, crate::obs::export::prometheus_text(&snap))
            }
            "/plan" => match self.parse_and_resolve(req) {
                Ok(served) => Response::bytes(200, served.bytes.as_ref().clone()),
                Err(e) => self.error_response(e),
            },
            "/plans" => match self.batch_plans(req) {
                Ok(resp) => resp,
                Err(e) => self.error_response(e),
            },
            "/counters" => match self.parse_and_resolve(req) {
                Ok(served) => match render_counters(&served) {
                    Ok(text) => Response::text(200, text),
                    Err(e) => Response::text(500, format!("{e}\n")),
                },
                Err(e) => self.error_response(e),
            },
            other => Response::text(
                404,
                format!(
                    "no route {other:?} (try /plan, /plans, /counters, /stats, /metrics, \
                     /healthz)\n"
                ),
            ),
        }
    }

    /// Batched plan resolution: `/plans?kernels=a,b,c&machine=..&budget=..`
    /// warms a whole universe in one round trip. Shared-parameter errors
    /// (machine, budget, prefetch, an empty kernel list) are a normal
    /// 400; per-kernel failures are reported in their own body line and
    /// never fail the batch.
    fn batch_plans(&self, req: &Request) -> std::result::Result<Response, ServeError> {
        let kernels = require_param(req, "kernels")?;
        let machine = require_param(req, "machine")?;
        let budget = parse_budget(req)?;
        let prefetch = parse_prefetch(req)?;
        let names: Vec<&str> =
            kernels.split(',').map(str::trim).filter(|k| !k.is_empty()).collect();
        if names.is_empty() {
            return Err(ServeError::BadRequest(
                "kernels must name at least one kernel (comma-separated)".to_string(),
            ));
        }
        let mut body = String::new();
        for kernel in names {
            match self.plan_bytes(kernel, machine, budget, prefetch) {
                Ok(served) => {
                    let source = format!("{:?}", served.source).to_ascii_lowercase();
                    body.push_str(&format!(
                        "kernel={kernel} status=ok source={source} bytes={}\n",
                        served.bytes.len()
                    ));
                }
                Err(e) => {
                    let msg = e.message().replace('\n', " ");
                    body.push_str(&format!(
                        "kernel={kernel} status=error code={} {msg}\n",
                        e.status()
                    ));
                }
            }
        }
        Ok(Response::text(200, body))
    }

    fn error_response(&self, e: ServeError) -> Response {
        if e.status() == 400 {
            self.counters.bad_requests.fetch_add(1, Ordering::SeqCst);
        }
        Response::text(e.status(), format!("{}\n", e.message()))
    }

    fn parse_and_resolve(&self, req: &Request) -> std::result::Result<Served, ServeError> {
        let kernel = require_param(req, "kernel")?;
        let machine = require_param(req, "machine")?;
        let budget = parse_budget(req)?;
        let prefetch = parse_prefetch(req)?;
        self.plan_bytes(kernel, machine, budget, prefetch)
    }
}

fn parse_budget(req: &Request) -> std::result::Result<u64, ServeError> {
    require_param(req, "budget")?.parse().map_err(|_| {
        ServeError::BadRequest(format!(
            "budget must be a byte count, got {:?}",
            req.param("budget").unwrap_or_default()
        ))
    })
}

fn parse_prefetch(req: &Request) -> std::result::Result<bool, ServeError> {
    match req.param("prefetch") {
        None | Some("on") | Some("true") | Some("1") => Ok(true),
        Some("off") | Some("false") | Some("0") => Ok(false),
        Some(other) => Err(ServeError::BadRequest(format!(
            "prefetch must be on|off|true|false|1|0, got {other:?}"
        ))),
    }
}

fn require_param<'r>(req: &'r Request, name: &str) -> std::result::Result<&'r str, ServeError> {
    match req.param(name) {
        Some(v) if !v.is_empty() => Ok(v),
        _ => Err(ServeError::BadRequest(format!(
            "missing required query parameter {name:?} \
             (grammar: /plan?kernel=..&machine=..&budget=..&prefetch=on|off)"
        ))),
    }
}

/// Render a served plan as human-readable predicted counters.
fn render_counters(served: &Served) -> Result<String> {
    let text = std::str::from_utf8(&served.bytes)
        .map_err(|e| format_err!("served plan is not UTF-8: {e}"))?;
    let p = TunedPlan::parse(text)?;
    let mut out = String::new();
    let mut push = |k: &str, v: String| {
        out.push_str(k);
        out.push('=');
        out.push_str(&v);
        out.push('\n');
    };
    push("kernel", p.kernel.clone());
    push("machine", p.machine.clone());
    push("budget_class", p.budget_class.to_string());
    push("budget_bytes", p.budget_bytes.to_string());
    push("prefetch", p.prefetch.to_string());
    push("predicted_gib_s", format!("{:.6}", p.predicted_gib));
    push("winner_probe_gib_s", format!("{:.6}", p.winner_probe_gib));
    push("baseline_probe_gib_s", format!("{:.6}", p.baseline_probe_gib));
    push("predicted_accesses_per_sec", format!("{:.3}", p.predicted_accesses_per_sec));
    push("l1_hit", format!("{:.6}", p.l1_hit));
    push("l2_hit", format!("{:.6}", p.l2_hit));
    push("l3_hit", format!("{:.6}", p.l3_hit));
    if let Some(s) = p.speedup_over_single() {
        push("speedup_over_single", format!("{s:.6}"));
    }
    push("source", format!("{:?}", served.source).to_ascii_lowercase());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_key_separates_every_coordinate() {
        let base = plan_key("mxv", "Coffee Lake", true, 21);
        assert_ne!(base, plan_key("jacobi-1d", "Coffee Lake", true, 21));
        assert_ne!(base, plan_key("mxv", "Zen 2", true, 21));
        assert_ne!(base, plan_key("mxv", "Coffee Lake", false, 21));
        assert_ne!(base, plan_key("mxv", "Coffee Lake", true, 22));
        assert_eq!(base, plan_key("mxv", "Coffee Lake", true, 21), "deterministic");
    }

    #[test]
    fn plan_key_length_prefix_blocks_concatenation_aliases() {
        assert_ne!(plan_key("ab", "c", true, 0), plan_key("a", "bc", true, 0));
    }

    #[test]
    fn miss_policy_names_round_trip() {
        for p in [MissPolicy::NotFound, MissPolicy::Tune] {
            assert_eq!(MissPolicy::from_name(p.cli_name()).unwrap(), p);
        }
        assert!(MissPolicy::from_name("panic").is_err());
    }

    #[test]
    fn serve_error_statuses() {
        assert_eq!(ServeError::BadRequest("x".into()).status(), 400);
        assert_eq!(ServeError::NotFound("x".into()).status(), 404);
        assert_eq!(ServeError::Internal("x".into()).status(), 500);
    }

    fn get(path: &str, query: &[(&str, &str)]) -> Request {
        Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query: query.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect(),
            close: false,
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("multistride_svc_{tag}_{}", std::process::id()))
    }

    fn service(on_miss: MissPolicy, store: ResultStore, dir: &std::path::Path) -> PlanService {
        PlanService::new(1 << 20, Policy::Lru, on_miss, PlanCache::new(dir.join("plans")), store)
    }

    fn body(resp: &Response) -> String {
        String::from_utf8_lossy(&resp.body).into_owned()
    }

    #[test]
    fn healthz_is_ok_on_a_healthy_store() {
        let dir = tmp("healthy");
        let svc = service(MissPolicy::NotFound, ResultStore::ephemeral(), &dir);
        let resp = svc.handle(&get("/healthz", &[]));
        assert_eq!(resp.status, 200);
        assert_eq!(body(&resp), "ok\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite pin: a store degraded to memory-only by a dead disk
    /// must surface through `/healthz` as `degraded` — still 200, so a
    /// liveness probe keeps the daemon up while a fleet probe can grep
    /// the condition — and through the `store_degraded` gauge.
    #[test]
    fn healthz_reports_degraded_store_but_stays_200() {
        use crate::config::coffee_lake;
        use crate::exec::vfs::{FaultIo, FaultPlan, RealIo, StoreIo};
        use crate::exec::SimPoint;
        use crate::kernels::micro::MicroOp;

        let dir = tmp("degraded");
        std::fs::remove_dir_all(&dir).ok();
        let io: Arc<dyn StoreIo> = Arc::new(FaultIo::new(Arc::new(RealIo), FaultPlan::dead_disk()));
        let store = ResultStore::persistent_with_io(
            dir.join("results"),
            crate::exec::segment::DEFAULT_ROLL_BYTES,
            io,
        );
        let mut engines = EngineCache::new();
        for strides in [1u32, 2, 4, 8] {
            let p = SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, strides, 1 << 20, true, false);
            store.get_or_run(&mut engines, &p).expect("a dead disk must not fail simulation");
        }
        assert!(store.stats().degraded, "test premise: the store must be degraded");

        let svc = service(MissPolicy::NotFound, store, &dir);
        let resp = svc.handle(&get("/healthz", &[]));
        assert_eq!(resp.status, 200, "degraded is a condition, not an outage");
        assert!(body(&resp).starts_with("degraded"), "got: {}", body(&resp));

        // The same condition is scrapeable as the store_degraded gauge.
        let metrics = svc.handle(&get("/metrics", &[]));
        assert_eq!(metrics.status, 200);
        assert!(body(&metrics).contains("store_degraded 1\n"), "got: {}", body(&metrics));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_exposes_serve_and_exec_counters() {
        let dir = tmp("metrics");
        let svc = service(MissPolicy::NotFound, ResultStore::ephemeral(), &dir);
        svc.handle(&get("/healthz", &[]));
        let resp = svc.handle(&get("/metrics", &[]));
        assert_eq!(resp.status, 200);
        let text = body(&resp);
        assert!(text.contains("# TYPE serve_pool_requests_total counter"), "got: {text}");
        assert!(text.contains("\nexec_requests_total "), "got: {text}");
        assert!(text.contains("# TYPE store_degraded gauge\nstore_degraded 0\n"), "got: {text}");
        assert!(
            text.contains("# TYPE serve_healthz_request_us histogram"),
            "the healthz request before the scrape must have recorded a latency\ngot: {text}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batched_plans_reports_per_kernel_status_without_failing_the_batch() {
        let dir = tmp("plans404");
        let svc = service(MissPolicy::NotFound, ResultStore::ephemeral(), &dir);
        let resp = svc.handle(&get(
            "/plans",
            &[
                ("kernels", "mxv,nosuchkernel"),
                ("machine", "coffee-lake"),
                ("budget", "2097152"),
            ],
        ));
        assert_eq!(resp.status, 200, "per-kernel misses never fail the batch: {}", body(&resp));
        let text = body(&resp);
        assert!(text.contains("kernel=mxv status=error code=404"), "got: {text}");
        assert!(text.contains("kernel=nosuchkernel status=error code=404"), "got: {text}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batched_plans_tunes_once_and_serves_the_duplicate_from_the_pool() {
        let dir = tmp("planstune");
        std::fs::remove_dir_all(&dir).ok();
        let svc = service(MissPolicy::Tune, ResultStore::ephemeral(), &dir);
        let resp = svc.handle(&get(
            "/plans",
            &[("kernels", "mxv, mxv"), ("machine", "coffee-lake"), ("budget", "2097152")],
        ));
        assert_eq!(resp.status, 200, "got: {}", body(&resp));
        let text = body(&resp);
        assert!(text.contains("kernel=mxv status=ok source=tuned"), "got: {text}");
        assert!(text.contains("kernel=mxv status=ok source=pool"), "got: {text}");
        assert_eq!(svc.stats().tunes, 1, "the duplicate must not re-tune");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batched_plans_shared_parameter_errors_are_a_400() {
        let dir = tmp("plansbad");
        let svc = service(MissPolicy::NotFound, ResultStore::ephemeral(), &dir);
        for query in [
            &[("machine", "coffee-lake"), ("budget", "1048576")][..],
            &[("kernels", " , "), ("machine", "coffee-lake"), ("budget", "1048576")],
            &[("kernels", "mxv"), ("machine", "coffee-lake"), ("budget", "lots")],
        ] {
            let resp = svc.handle(&get("/plans", query));
            assert_eq!(resp.status, 400, "{query:?} got: {}", body(&resp));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
