//! Pluggable eviction policies for the serving buffer pool.
//!
//! The pool ([`super::pool::BufferPool`]) tracks *what* is cached and how
//! many bytes it costs; a [`Replacer`] tracks *which entry dies next*.
//! Keeping the two concerns behind one small trait is what makes the
//! policy a CLI knob (`repro serve --policy lru|clock|sieve`) and lets
//! the bench measure the policies against each other on identical
//! request streams.
//!
//! Three policies ship, the classic buffer-manager lattice:
//!
//! * [`LruReplacer`] — exact least-recently-used. Every touch stamps the
//!   key with a monotonically increasing tick; eviction removes the
//!   minimum stamp. O(1) touch, O(n) evict — the pool holds at most a
//!   few thousand plan-sized entries, so the scan is cheaper than
//!   maintaining an intrusive list.
//! * [`ClockReplacer`] — the second-chance approximation. Keys sit on a
//!   ring in insertion order with a referenced bit; a touch sets the
//!   bit. The eviction hand sweeps the ring: a referenced key is spared
//!   (bit cleared, pushed behind the hand), the first unreferenced key
//!   is the victim. New keys join immediately *behind* the hand, so
//!   they are visited last in the current sweep.
//! * [`SieveReplacer`] — SIEVE (NSDI'24): a FIFO queue with a visited
//!   bit and a hand that moves from the oldest entry toward the newest.
//!   A hit only sets the visited bit — entries never move, which is
//!   what makes the policy scan-resistant. The hand clears visited bits
//!   as it sweeps and evicts the first unvisited entry it meets; new
//!   entries join at the newest end, and the hand wraps back to the
//!   oldest end when it runs off the queue.
//!
//! Contract shared by all three (pinned differentially against naive
//! reference models in `tests/serve_pool.rs`):
//!
//! * `touch(k)` inserts an absent key and marks a present one used;
//! * `evict()` removes and returns exactly one tracked key (`None` when
//!   empty) — the pool then drops that entry's bytes;
//! * `remove(k)` forgets a key without counting as an eviction;
//! * `len()` equals the number of tracked keys at all times.
//!
//! To add a policy: implement the trait, extend [`Policy`] and its
//! name tables, and add an arm to [`Policy::new_replacer`] — the CLI,
//! pool, bench sweep and differential wall all enumerate
//! [`Policy::all`], so the new policy is picked up everywhere at once.

use std::collections::{HashMap, VecDeque};

use crate::{format_err, Result};

/// Eviction-policy selector (the `--policy` CLI knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Lru,
    Clock,
    Sieve,
}

impl Policy {
    pub fn all() -> [Policy; 3] {
        [Self::Lru, Self::Clock, Self::Sieve]
    }

    /// Canonical CLI spelling (what `--policy` accepts and help prints).
    pub fn cli_name(self) -> &'static str {
        match self {
            Self::Lru => "lru",
            Self::Clock => "clock",
            Self::Sieve => "sieve",
        }
    }

    /// Parse a CLI spelling; the error lists the valid set.
    pub fn from_name(name: &str) -> Result<Policy> {
        match name.to_ascii_lowercase().as_str() {
            "lru" => Ok(Self::Lru),
            "clock" => Ok(Self::Clock),
            "sieve" => Ok(Self::Sieve),
            other => Err(format_err!(
                "unknown eviction policy {other:?} (expected one of: lru, clock, sieve)"
            )),
        }
    }

    /// A fresh replacer implementing this policy.
    pub fn new_replacer(self) -> Box<dyn Replacer> {
        match self {
            Self::Lru => Box::new(LruReplacer::new()),
            Self::Clock => Box::new(ClockReplacer::new()),
            Self::Sieve => Box::new(SieveReplacer::new()),
        }
    }
}

/// The eviction-order contract the pool drives (see the module docs).
pub trait Replacer: Send {
    /// Which policy this replacer implements.
    fn policy(&self) -> Policy;
    /// Insert `key` if absent; mark it used either way.
    fn touch(&mut self, key: u64);
    /// Forget `key` (no-op when untracked). Not an eviction.
    fn remove(&mut self, key: u64);
    /// Choose, forget and return the next victim (`None` when empty).
    fn evict(&mut self) -> Option<u64>;
    /// Number of tracked keys.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Exact LRU via monotonic stamps: the victim is the minimum stamp.
pub struct LruReplacer {
    stamps: HashMap<u64, u64>,
    tick: u64,
}

impl LruReplacer {
    pub fn new() -> Self {
        Self { stamps: HashMap::new(), tick: 0 }
    }
}

impl Default for LruReplacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Replacer for LruReplacer {
    fn policy(&self) -> Policy {
        Policy::Lru
    }

    fn touch(&mut self, key: u64) {
        self.tick += 1;
        self.stamps.insert(key, self.tick);
    }

    fn remove(&mut self, key: u64) {
        self.stamps.remove(&key);
    }

    fn evict(&mut self) -> Option<u64> {
        // Stamps are unique, so the minimum is a deterministic victim
        // regardless of HashMap iteration order.
        let victim = self.stamps.iter().min_by_key(|(_, &stamp)| stamp).map(|(&k, _)| k)?;
        self.stamps.remove(&victim);
        Some(victim)
    }

    fn len(&self) -> usize {
        self.stamps.len()
    }
}

/// Second-chance clock. The ring front is the hand position; sparing a
/// referenced key rotates it behind the hand. Removal is eager — a
/// lazily-skipped stale slot would collide with a re-touched key's new
/// slot and corrupt the sweep order.
pub struct ClockReplacer {
    ring: VecDeque<u64>,
    /// key → referenced bit; always in lockstep with `ring`.
    referenced: HashMap<u64, bool>,
}

impl ClockReplacer {
    pub fn new() -> Self {
        Self { ring: VecDeque::new(), referenced: HashMap::new() }
    }
}

impl Default for ClockReplacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Replacer for ClockReplacer {
    fn policy(&self) -> Policy {
        Policy::Clock
    }

    fn touch(&mut self, key: u64) {
        match self.referenced.get_mut(&key) {
            Some(bit) => *bit = true,
            None => {
                // New keys join behind the hand (ring back): the sweep
                // in progress visits them last.
                self.referenced.insert(key, true);
                self.ring.push_back(key);
            }
        }
    }

    fn remove(&mut self, key: u64) {
        if self.referenced.remove(&key).is_some() {
            if let Some(idx) = self.ring.iter().position(|&k| k == key) {
                self.ring.remove(idx);
            }
        }
    }

    fn evict(&mut self) -> Option<u64> {
        // Terminates within two sweeps: the first pass clears every
        // referenced bit, and bits are only set by touch().
        loop {
            let key = self.ring.pop_front()?;
            let bit = self.referenced.get_mut(&key).expect("ring and map agree");
            if *bit {
                // Second chance: clear and rotate behind the hand.
                *bit = false;
                self.ring.push_back(key);
            } else {
                self.referenced.remove(&key);
                return Some(key);
            }
        }
    }

    fn len(&self) -> usize {
        self.referenced.len()
    }
}

/// SIEVE. Queue front = oldest, back = newest; `hand` indexes the next
/// sweep position from the oldest side. Hits set the visited bit and
/// never move the entry.
pub struct SieveReplacer {
    /// Oldest at index 0, newest at the end.
    queue: VecDeque<u64>,
    visited: HashMap<u64, bool>,
    /// Next sweep index into `queue`; wraps to 0 (the oldest survivor)
    /// when it runs off the newest end.
    hand: usize,
}

impl SieveReplacer {
    pub fn new() -> Self {
        Self { queue: VecDeque::new(), visited: HashMap::new(), hand: 0 }
    }
}

impl Default for SieveReplacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Replacer for SieveReplacer {
    fn policy(&self) -> Policy {
        Policy::Sieve
    }

    fn touch(&mut self, key: u64) {
        match self.visited.get_mut(&key) {
            Some(bit) => *bit = true,
            None => {
                // New entries join unvisited at the newest end; the hand
                // index (counted from the oldest end) is unaffected.
                self.visited.insert(key, false);
                self.queue.push_back(key);
            }
        }
    }

    fn remove(&mut self, key: u64) {
        if self.visited.remove(&key).is_none() {
            return;
        }
        if let Some(idx) = self.queue.iter().position(|&k| k == key) {
            self.queue.remove(idx);
            // Keep the hand on the same logical neighbour: entries at
            // or past the removed index shift down by one.
            if idx < self.hand {
                self.hand -= 1;
            }
        }
    }

    fn evict(&mut self) -> Option<u64> {
        if self.queue.is_empty() {
            return None;
        }
        // Terminates: each loop iteration either evicts or clears one
        // visited bit, and bits are only set by touch().
        loop {
            if self.hand >= self.queue.len() {
                self.hand = 0;
            }
            let key = self.queue[self.hand];
            let bit = self.visited.get_mut(&key).expect("queue and map agree");
            if *bit {
                *bit = false;
                self.hand += 1;
            } else {
                self.queue.remove(self.hand);
                self.visited.remove(&key);
                // The hand now indexes the evictee's next-newer
                // neighbour (or wraps on the next call).
                return Some(key);
            }
        }
    }

    fn len(&self) -> usize {
        self.visited.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(r: &mut dyn Replacer) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(k) = r.evict() {
            out.push(k);
        }
        out
    }

    #[test]
    fn policy_names_round_trip() {
        for p in Policy::all() {
            assert_eq!(Policy::from_name(p.cli_name()).unwrap(), p);
            assert_eq!(p.new_replacer().policy(), p);
        }
        assert!(Policy::from_name("mru").is_err());
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut r = LruReplacer::new();
        for k in [1, 2, 3] {
            r.touch(k);
        }
        r.touch(1); // 2 is now the least recent
        assert_eq!(r.evict(), Some(2));
        assert_eq!(drain(&mut r), vec![3, 1]);
        assert_eq!(r.evict(), None);
    }

    #[test]
    fn clock_gives_second_chances() {
        let mut r = ClockReplacer::new();
        for k in [1, 2, 3] {
            r.touch(k);
        }
        // All referenced: the first sweep clears 1 and 2, then 3... and
        // wraps — every key gets one pass before the oldest dies.
        assert_eq!(r.evict(), Some(1));
        r.touch(2); // re-referenced: spared again
        assert_eq!(r.evict(), Some(3));
        assert_eq!(r.evict(), Some(2));
        assert_eq!(r.evict(), None);
    }

    #[test]
    fn sieve_hits_do_not_move_entries() {
        let mut r = SieveReplacer::new();
        for k in [1, 2, 3] {
            r.touch(k);
        }
        r.touch(1); // visited; stays the oldest
        assert_eq!(r.evict(), Some(2), "hand spares visited 1, evicts unvisited 2");
        r.touch(4);
        assert_eq!(r.evict(), Some(3), "hand continues from the old position");
        // The hand now points at 4 (unvisited, newest); 1's bit was
        // cleared by the first sweep, so it goes after the wrap.
        assert_eq!(drain(&mut r), vec![4, 1]);
    }

    #[test]
    fn remove_is_not_an_eviction_and_keeps_order_sane() {
        for policy in Policy::all() {
            let mut r = policy.new_replacer();
            for k in [1, 2, 3, 4] {
                r.touch(k);
            }
            r.remove(2);
            r.remove(99); // untracked: no-op
            assert_eq!(r.len(), 3);
            let mut rest = drain(r.as_mut());
            rest.sort_unstable();
            assert_eq!(rest, vec![1, 3, 4], "{policy:?}");
        }
    }

    #[test]
    fn empty_replacer_evicts_none() {
        for policy in Policy::all() {
            let mut r = policy.new_replacer();
            assert!(r.is_empty());
            assert_eq!(r.evict(), None, "{policy:?}");
            r.touch(7);
            assert_eq!(r.evict(), Some(7));
            assert_eq!(r.evict(), None);
        }
    }
}
