//! Bounded in-memory buffer pool fronting the plan stores.
//!
//! The pool is the serving layer's only cache: a byte-budgeted map from
//! a plan-identity key (see `super::service::plan_key`) to the exact
//! serialized plan bytes, with eviction order delegated to a pluggable
//! [`Replacer`](super::replacer::Replacer). The hard contract — pinned
//! by a concurrent wall in `tests/serve_pool.rs` — is that the sum of
//! cached entry sizes **never** exceeds `capacity_bytes`, not even
//! transiently: insertion evicts first, inserts after, all under one
//! mutex.
//!
//! Entries larger than the whole budget are refused outright (counted
//! in `rejected_oversize`) instead of flushing the pool for a single
//! request. Values are handed out as `Arc<Vec<u8>>`, so an entry
//! evicted mid-flight stays alive for the response already holding it.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::replacer::{Policy, Replacer};

/// Point-in-time counters, readable while the daemon runs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub rejected_oversize: u64,
    pub current_bytes: u64,
    pub current_entries: u64,
    pub capacity_bytes: u64,
}

impl PoolStats {
    /// Hit ratio in percent (0 when the pool was never asked).
    pub fn hit_pct(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        100.0 * self.hits as f64 / self.requests as f64
    }
}

struct Inner {
    entries: HashMap<u64, Arc<Vec<u8>>>,
    bytes: u64,
    replacer: Box<dyn Replacer>,
    stats: PoolStats,
}

/// Byte-bounded cache with pluggable eviction. Shared by `&self`; all
/// state sits behind one mutex (entries are small and the critical
/// sections copy nothing but an `Arc`).
pub struct BufferPool {
    capacity_bytes: u64,
    inner: Mutex<Inner>,
}

impl BufferPool {
    pub fn new(capacity_bytes: u64, policy: Policy) -> Self {
        Self {
            capacity_bytes,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                bytes: 0,
                replacer: policy.new_replacer(),
                stats: PoolStats { capacity_bytes, ..PoolStats::default() },
            }),
        }
    }

    pub fn policy(&self) -> Policy {
        self.inner.lock().unwrap().replacer.policy()
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Look up `key`, counting a hit or miss and updating recency.
    pub fn get(&self, key: u64) -> Option<Arc<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        inner.stats.requests += 1;
        match inner.entries.get(&key).cloned() {
            Some(value) => {
                inner.stats.hits += 1;
                inner.replacer.touch(key);
                Some(value)
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// Cache `value` under `key`, evicting until it fits. Returns false
    /// (and caches nothing) when the value alone exceeds the budget.
    /// Re-inserting a present key replaces the bytes in place.
    pub fn insert(&self, key: u64, value: Arc<Vec<u8>>) -> bool {
        let size = value.len() as u64;
        let mut inner = self.inner.lock().unwrap();
        if size > self.capacity_bytes {
            inner.stats.rejected_oversize += 1;
            return false;
        }
        if let Some(old) = inner.entries.remove(&key) {
            // Replacement: release the old bytes first so the fit check
            // sees the true residual load.
            inner.bytes -= old.len() as u64;
            inner.replacer.remove(key);
        }
        while inner.bytes + size > self.capacity_bytes {
            let victim = inner.replacer.evict().expect("bytes > 0 implies a tracked key");
            let dropped = inner.entries.remove(&victim).expect("replacer tracks only residents");
            inner.bytes -= dropped.len() as u64;
            inner.stats.evictions += 1;
        }
        inner.bytes += size;
        inner.entries.insert(key, value);
        inner.replacer.touch(key);
        inner.stats.insertions += 1;
        true
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        let mut s = inner.stats;
        s.current_bytes = inner.bytes;
        s.current_entries = inner.entries.len() as u64;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val(n: usize, fill: u8) -> Arc<Vec<u8>> {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn insert_then_get_round_trips() {
        let pool = BufferPool::new(100, Policy::Lru);
        assert!(pool.insert(1, val(40, 0xA)));
        assert_eq!(pool.get(1).unwrap().len(), 40);
        assert!(pool.get(2).is_none());
        let s = pool.stats();
        assert_eq!((s.requests, s.hits, s.misses), (2, 1, 1));
        assert_eq!((s.current_bytes, s.current_entries), (40, 1));
    }

    #[test]
    fn byte_bound_holds_and_evictions_are_counted() {
        let pool = BufferPool::new(100, Policy::Lru);
        for key in 0..5u64 {
            assert!(pool.insert(key, val(40, key as u8)));
            assert!(pool.stats().current_bytes <= 100);
        }
        let s = pool.stats();
        assert_eq!(s.current_entries, 2, "100-byte budget holds two 40-byte plans");
        assert_eq!(s.evictions, 3);
        // LRU: the two newest keys survive.
        assert!(pool.get(3).is_some() && pool.get(4).is_some());
    }

    #[test]
    fn oversize_values_are_rejected_not_cached() {
        let pool = BufferPool::new(64, Policy::Sieve);
        assert!(pool.insert(1, val(10, 1)));
        assert!(!pool.insert(2, val(65, 2)), "larger than the whole budget");
        let s = pool.stats();
        assert_eq!(s.rejected_oversize, 1);
        assert_eq!(s.current_entries, 1, "the resident entry is untouched");
        assert!(pool.get(2).is_none());
    }

    #[test]
    fn reinsert_replaces_in_place() {
        let pool = BufferPool::new(100, Policy::Clock);
        assert!(pool.insert(7, val(60, 1)));
        assert!(pool.insert(7, val(80, 2)), "replacement releases the old bytes first");
        let s = pool.stats();
        assert_eq!((s.current_bytes, s.current_entries, s.evictions), (80, 1, 0));
        assert_eq!(pool.get(7).unwrap()[0], 2);
    }

    #[test]
    fn evicted_arcs_stay_alive_for_in_flight_readers() {
        let pool = BufferPool::new(50, Policy::Lru);
        pool.insert(1, val(50, 0xEE));
        let held = pool.get(1).unwrap();
        pool.insert(2, val(50, 0x22)); // evicts 1
        assert!(pool.get(1).is_none());
        assert_eq!(held.len(), 50, "response already holding the Arc is unaffected");
        assert!(held.iter().all(|&b| b == 0xEE));
    }

    #[test]
    fn hit_pct_reads_back() {
        let pool = BufferPool::new(100, Policy::Lru);
        assert_eq!(pool.stats().hit_pct(), 0.0);
        pool.insert(1, val(10, 0));
        pool.get(1);
        pool.get(2);
        assert!((pool.stats().hit_pct() - 50.0).abs() < 1e-9);
    }
}
