//! `repro serve` — the plan-serving daemon.
//!
//! The north star is serving tuned plans and predicted counters to
//! heavy traffic, and everything needed already sits content-addressed
//! on disk: `<artifacts>/plans` (the [`PlanCache`](crate::tune::PlanCache))
//! and `<artifacts>/results` (the segment
//! [`ResultStore`](crate::exec::ResultStore)). This module puts an HTTP
//! front on those stores:
//!
//! * [`http`] — hand-rolled, dependency-free HTTP/1.1 over
//!   `std::net::TcpListener` (keep-alive, bounded heads, scripted
//!   client for tests and the bench load generator);
//! * [`replacer`] — the pluggable eviction lattice (LRU / Clock /
//!   SIEVE) behind one [`Replacer`] trait;
//! * [`pool`] — the bounded [`BufferPool`]: a byte-budgeted cache of
//!   serialized plans whose bound is never exceeded, not even
//!   transiently;
//! * [`service`] — the [`PlanService`]: endpoint grammar, pool → disk
//!   → miss-policy resolution, single-flight tune-on-demand, counters.
//!
//! This file owns the CLI surface (`parse_serve_cli`, mirroring
//! `exec::lifecycle::parse_store_cli`: serve-specific flags out,
//! generic flags left for the caller's option parser) and the daemon
//! entry point [`run_serve`]. The daemon's lifetime summary is the
//! greppable `[serve]` line (see `report::figures::render_serve_summary`),
//! printed on shutdown and served live at `GET /stats`.

pub mod http;
pub mod pool;
pub mod replacer;
pub mod service;

use std::sync::Arc;

use crate::exec::ResultStore;
use crate::tune::PlanCache;
use crate::{ensure, format_err, Result};

pub use http::{Client, HttpServer, Request, Response, ServerControl};
pub use pool::{BufferPool, PoolStats};
pub use replacer::{Policy, Replacer};
pub use service::{MissPolicy, PlanService, PlanSource, ServeError, ServeStats, Served};

/// Default listening port (deliberately unprivileged and greppable).
pub const DEFAULT_PORT: u16 = 7878;
/// Default pool budget: 64 MiB holds tens of thousands of plans —
/// plans are a few hundred bytes, so the bound exists to make eviction
/// *observable* under bench pressure, not because plans are big.
pub const DEFAULT_POOL_BYTES: u64 = 64 * 1024 * 1024;

/// Parsed `repro serve` options (the serve-specific flags only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeOpts {
    pub port: u16,
    pub pool_bytes: u64,
    pub policy: Policy,
    pub on_miss: MissPolicy,
    /// Stop after answering exactly this many requests (the request
    /// that exhausts the budget is still answered in full). This is
    /// what lets CI script a deterministic daemon lifetime without
    /// signal handling; absent means serve forever.
    pub max_requests: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self {
            port: DEFAULT_PORT,
            pool_bytes: DEFAULT_POOL_BYTES,
            policy: Policy::Lru,
            on_miss: MissPolicy::NotFound,
            max_requests: None,
        }
    }
}

/// Parse `repro serve …` argv: the daemon flags, returning the leftover
/// args for the generic option parser (`--plans`, `--results`,
/// `--artifacts`, `--cold`, `--smoke`, …).
pub fn parse_serve_cli(args: &[String]) -> Result<(ServeOpts, Vec<String>)> {
    fn value<'a>(it: &mut std::slice::Iter<'a, String>, flag: &str) -> Result<&'a String> {
        it.next().ok_or_else(|| format_err!("serve: {flag} needs a value"))
    }
    let mut o = ServeOpts::default();
    let mut rest = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--port" => {
                let v = value(&mut it, "--port")?;
                o.port = v
                    .parse()
                    .map_err(|_| format_err!("serve: --port must be 0..=65535, got {v:?}"))?;
            }
            "--pool-bytes" => {
                let v = value(&mut it, "--pool-bytes")?;
                o.pool_bytes = v.parse().map_err(|_| {
                    format_err!("serve: --pool-bytes must be a byte count, got {v:?}")
                })?;
                ensure!(o.pool_bytes > 0, "serve: --pool-bytes must be positive");
            }
            "--policy" => o.policy = Policy::from_name(value(&mut it, "--policy")?)?,
            "--on-miss" => o.on_miss = MissPolicy::from_name(value(&mut it, "--on-miss")?)?,
            "--max-requests" => {
                let v = value(&mut it, "--max-requests")?;
                let n: u64 = v.parse().map_err(|_| {
                    format_err!("serve: --max-requests must be a count, got {v:?}")
                })?;
                ensure!(n > 0, "serve: --max-requests must be positive");
                o.max_requests = Some(n);
            }
            _ => rest.push(a.clone()),
        }
    }
    Ok((o, rest))
}

/// Run the daemon until its [`ServerControl`] stops it (request budget,
/// or an external `request_stop`). Blocks; returns the lifetime stats
/// for the `[serve]` summary line.
pub fn run_serve(opts: ServeOpts, plans: PlanCache, store: ResultStore) -> Result<ServeStats> {
    let service =
        Arc::new(PlanService::new(opts.pool_bytes, opts.policy, opts.on_miss, plans, store));
    let server = HttpServer::bind(opts.port)?;
    let ctl = ServerControl::new(opts.max_requests);
    println!(
        "[serve] listening on 127.0.0.1:{} (policy {}, pool {} B, on-miss {}{})",
        server.port(),
        opts.policy.cli_name(),
        opts.pool_bytes,
        opts.on_miss.cli_name(),
        match opts.max_requests {
            Some(n) => format!(", stopping after {n} request(s)"),
            None => String::new(),
        },
    );
    let handler = {
        let service = service.clone();
        Arc::new(move |req: &Request| service.handle(req))
    };
    server.serve(handler, ctl)?;
    Ok(service.stats())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn serve_cli_defaults_and_passthrough() {
        let (o, rest) = parse_serve_cli(&argv(&["--results", "r", "--smoke"])).unwrap();
        assert_eq!(o, ServeOpts::default());
        assert_eq!(rest, argv(&["--results", "r", "--smoke"]));
    }

    #[test]
    fn serve_cli_parses_every_flag() {
        let (o, rest) = parse_serve_cli(&argv(&[
            "--port",
            "0",
            "--pool-bytes",
            "4096",
            "--policy",
            "sieve",
            "--on-miss",
            "tune",
            "--max-requests",
            "7",
            "--plans",
            "p",
        ]))
        .unwrap();
        assert_eq!(o.port, 0);
        assert_eq!(o.pool_bytes, 4096);
        assert_eq!(o.policy, Policy::Sieve);
        assert_eq!(o.on_miss, MissPolicy::Tune);
        assert_eq!(o.max_requests, Some(7));
        assert_eq!(rest, argv(&["--plans", "p"]));
    }

    #[test]
    fn serve_cli_rejects_malformed_values() {
        for bad in [
            &["--port"][..],
            &["--port", "notaport"],
            &["--pool-bytes", "big"],
            &["--pool-bytes", "0"],
            &["--policy", "mru"],
            &["--on-miss", "panic"],
            &["--max-requests", "0"],
            &["--max-requests", "many"],
        ] {
            assert!(parse_serve_cli(&argv(bad)).is_err(), "{bad:?} must be refused");
        }
    }
}
