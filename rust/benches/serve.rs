//! Bench harness — the plan-serving daemon: request throughput, tail
//! latency, and buffer-pool hit ratios per eviction policy.
//!
//! "Serving heavy traffic" is the north star, so this harness makes it
//! a number three ways:
//!
//! 1. **Cold vs warm over HTTP** — a real daemon on a loopback socket,
//!    scripted keep-alive clients: the cold sweep pays disk reads, the
//!    warm pass runs entirely out of the bounded pool. Requests/s plus
//!    p50/p99 per-request latency (the `p50_latency_us`/`p99_latency_us`
//!    fields) come from the warm pass.
//! 2. **Per-policy hit ratios** — the same skewed trace replayed through
//!    a pool deliberately too small for the working set, once per
//!    policy (LRU / Clock / SIEVE); the `hit_pct_<policy>` fields and a
//!    warm-ratio assert make "the pool works" checkable.
//! 3. **Byte identity** — every 200 response is compared against the
//!    plan file the tuner wrote; a single divergent byte aborts the
//!    bench.
//!
//! Knobs (environment):
//! * `MULTISTRIDE_SERVE_BYTES` — per-kernel tuning budget in bytes
//!   (default 4 MiB; CI runs a reduced size).
//! * `MULTISTRIDE_SERVE_KERNELS` — how many registry kernels to tune
//!   and serve (default 4).
//! * `MULTISTRIDE_SERVE_REQUESTS` — warm-pass request count per client
//!   thread (default 1000, 4 threads).
//! * `MULTISTRIDE_BENCH_JSON` — output path (default `BENCH_serve.json`).

mod common;

use std::sync::Arc;
use std::time::Instant;

use common::{env_u64, stage, write_bench_json, JsonScenario};
use multistride::config::MachinePreset;
use multistride::coordinator::experiments::EngineCache;
use multistride::exec::ResultStore;
use multistride::serve::{
    Client, HttpServer, MissPolicy, PlanService, Policy, Request, ServerControl,
};
use multistride::tune::plan::budget_class;
use multistride::tune::{PlanCache, Tuner};
use multistride::util::Rng;

const CLIENT_THREADS: usize = 4;

fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * p).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn main() {
    let budget = env_u64("MULTISTRIDE_SERVE_BYTES", 4 * 1024 * 1024);
    let n_kernels = env_u64("MULTISTRIDE_SERVE_KERNELS", 4) as usize;
    let per_client = env_u64("MULTISTRIDE_SERVE_REQUESTS", 1000);
    let machine = MachinePreset::CoffeeLake;
    let cfg = machine.config();

    let dir = std::env::temp_dir().join(format!("multistride_serve_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let plans = PlanCache::new(&dir);

    // Warm the plan store the way `repro tune` would: one search per
    // kernel, winners persisted. The daemon under test never searches.
    let kernels: Vec<String> = multistride::runtime::universe_names(budget)
        .into_iter()
        .take(n_kernels)
        .collect();
    assert!(!kernels.is_empty(), "registry must not be empty");
    let expected: Vec<(String, Vec<u8>)> = stage("tune plans to disk", || {
        let tuner = Tuner::new(cfg, budget);
        let store = ResultStore::ephemeral();
        let mut engines = EngineCache::new();
        kernels
            .iter()
            .map(|k| {
                tuner.tune_on(&store, &mut engines, &plans, k, false).expect("tune succeeds");
                let path = plans.path_for(k, cfg.name, true, budget_class(budget));
                (k.clone(), std::fs::read(&path).expect("plan file exists"))
            })
            .collect()
    });
    let mut results = Vec::new();

    // ---------------------------------------------------------------
    // 1. HTTP: cold sweep, then a multi-client warm pass.
    // ---------------------------------------------------------------
    let service = Arc::new(PlanService::new(
        64 * 1024 * 1024,
        Policy::Lru,
        MissPolicy::NotFound,
        plans.clone(),
        ResultStore::ephemeral(),
    ));
    let server = HttpServer::bind(0).expect("bind port 0");
    let port = server.port();
    let ctl = ServerControl::new(None);
    let handler = {
        let service = service.clone();
        Arc::new(move |req: &Request| service.handle(req))
    };
    let join = {
        let ctl = ctl.clone();
        std::thread::spawn(move || server.serve(handler, ctl))
    };
    let url_for =
        |k: &str| format!("/plan?kernel={k}&machine={}&budget={budget}", machine.cli_name());

    let t = Instant::now();
    {
        let mut c = Client::connect(port).expect("connect");
        for (k, want) in &expected {
            let (status, body) = c.get(&url_for(k)).expect("cold request");
            assert_eq!(status, 200, "cold serve of {k}");
            assert_eq!(&body, want, "cold HTTP bytes == tuner plan file for {k}");
        }
    }
    let cold_secs = t.elapsed().as_secs_f64();
    println!(
        "{:>42}: {:>8.2} requests/s ({} requests, {cold_secs:.4} s)",
        "http plan serve, cold (disk)",
        expected.len() as f64 / cold_secs,
        expected.len(),
    );
    results.push(JsonScenario {
        label: "http plan serve, cold (disk)".into(),
        unit: "requests",
        count: expected.len() as u64,
        seconds: cold_secs,
    });

    let expected = Arc::new(expected);
    let t = Instant::now();
    let clients: Vec<_> = (0..CLIENT_THREADS)
        .map(|tid| {
            let expected = expected.clone();
            let machine_name = machine.cli_name().to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(port).expect("connect");
                let mut rng = Rng::new(0x5E12E + tid as u64);
                let mut lat_us = Vec::with_capacity(per_client as usize);
                for _ in 0..per_client {
                    let (k, want) = &expected[rng.below(expected.len() as u64) as usize];
                    let url =
                        format!("/plan?kernel={k}&machine={machine_name}&budget={budget}");
                    let t = Instant::now();
                    let (status, body) = c.get(&url).expect("warm request");
                    lat_us.push(t.elapsed().as_micros() as u64);
                    assert_eq!(status, 200);
                    assert_eq!(&body, want, "warm HTTP bytes == tuner plan file for {k}");
                }
                lat_us
            })
        })
        .collect();
    let mut lat_us: Vec<u64> =
        clients.into_iter().flat_map(|c| c.join().expect("client thread")).collect();
    let warm_secs = t.elapsed().as_secs_f64();
    lat_us.sort_unstable();
    let (p50, p99) = (percentile(&lat_us, 0.50), percentile(&lat_us, 0.99));
    let warm_requests = lat_us.len() as u64;
    println!(
        "{:>42}: {:>8.2} requests/s ({warm_requests} requests, {CLIENT_THREADS} clients, \
         p50 {p50} us, p99 {p99} us)",
        "http plan serve, warm (pool)",
        warm_requests as f64 / warm_secs,
    );
    results.push(JsonScenario {
        label: "http plan serve, warm (pool)".into(),
        unit: "requests",
        count: warm_requests,
        seconds: warm_secs,
    });
    ctl.request_stop();
    join.join().expect("server thread").expect("server exits cleanly");
    let warm_stats = service.stats();
    assert!(warm_stats.pool.hits > 0, "warm pass must hit the pool");
    assert!(
        warm_stats.pool.hit_pct() > 0.0,
        "warm hit ratio must be positive, got {:?}",
        warm_stats.pool
    );
    println!("{}", multistride::report::figures::render_serve_summary(&warm_stats).trim_end());

    // ---------------------------------------------------------------
    // 2. Per-policy hit ratios: pool too small for the working set,
    //    identical skewed trace (70% of traffic on two hot kernels).
    // ---------------------------------------------------------------
    let total_bytes: u64 = expected.iter().map(|(_, b)| b.len() as u64).sum();
    let pool_bytes = (total_bytes * 6 / 10).max(1);
    let trace_len = 20_000u64;
    let mut policy_hit_pct: Vec<(&'static str, u64)> = Vec::new();
    for policy in Policy::all() {
        let service = PlanService::new(
            pool_bytes,
            policy,
            MissPolicy::NotFound,
            plans.clone(),
            ResultStore::ephemeral(),
        );
        let mut rng = Rng::new(0x9001);
        let t = Instant::now();
        for _ in 0..trace_len {
            let idx = if rng.below(10) < 7 {
                (rng.below(2) as usize).min(expected.len() - 1)
            } else {
                rng.below(expected.len() as u64) as usize
            };
            let (k, want) = &expected[idx];
            let served = service
                .plan_bytes(k, machine.cli_name(), budget, true)
                .expect("trace request resolves");
            assert_eq!(&*served.bytes, want, "policy {policy:?}: bytes stay identical");
        }
        let secs = t.elapsed().as_secs_f64();
        let stats = service.stats();
        assert_eq!(stats.pool.requests, trace_len);
        assert!(
            stats.pool.hit_pct() > 0.0,
            "{policy:?}: skewed trace must produce hits, got {:?}",
            stats.pool
        );
        assert!(stats.pool.current_bytes <= pool_bytes, "{policy:?}: byte bound holds");
        println!(
            "{:>42}: {:>8.2} requests/s ({:.1}% pool hits, {} evictions)",
            format!("pool policy {}, skewed trace", policy.cli_name()),
            trace_len as f64 / secs,
            stats.pool.hit_pct(),
            stats.pool.evictions,
        );
        results.push(JsonScenario {
            label: format!("pool policy {}, skewed trace", policy.cli_name()),
            unit: "requests",
            count: trace_len,
            seconds: secs,
        });
        policy_hit_pct.push((policy.cli_name(), stats.pool.hit_pct().round() as u64));
    }

    let mut extra: Vec<(&str, u64)> = vec![
        ("budget_bytes", budget),
        ("kernels", expected.len() as u64),
        ("pool_bytes_policy_runs", pool_bytes),
        ("client_threads", CLIENT_THREADS as u64),
        ("p50_latency_us", p50),
        ("p99_latency_us", p99),
        ("warm_hit_pct", warm_stats.pool.hit_pct().round() as u64),
    ];
    let named: Vec<(String, u64)> =
        policy_hit_pct.iter().map(|(n, v)| (format!("hit_pct_{n}"), *v)).collect();
    extra.extend(named.iter().map(|(n, v)| (n.as_str(), *v)));

    let json_path = std::env::var("MULTISTRIDE_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    write_bench_json(&json_path, "serve", &extra, &results);
    std::fs::remove_dir_all(&dir).ok();
}
