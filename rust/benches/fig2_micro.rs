//! Bench harness — Figure 2: micro-benchmark throughput for every
//! data-movement instruction class across stride counts, prefetcher
//! on/off, on the Coffee Lake preset (the paper's §4 platform).

mod common;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::figure2;
use multistride::report::figures::render_micro_grid;

fn main() {
    let scale = common::scale();
    let points = common::stage("figure 2 grid", || figure2(coffee_lake(), scale, false));
    print!("{}", render_micro_grid(&points, "Figure 2 — micro-benchmark throughput"));

    // Headline check the paper states in §4.3: ~33% read gain at 16 strides.
    let at = |s: u32, pf: bool| {
        points
            .iter()
            .find(|p| {
                p.strides == s
                    && p.prefetch == pf
                    && !p.interleaved
                    && p.op == multistride::kernels::micro::MicroOp::LoadAligned
            })
            .map(|p| p.throughput_gib)
            .unwrap_or(0.0)
    };
    println!(
        "\naligned-read gain at 16 strides (pf on):  {:.2}x   (paper: 1.33x)",
        at(16, true) / at(1, true)
    );
    println!(
        "aligned-read gain at 16 strides (pf off): {:.2}x   (paper: ≤1.00x)",
        at(16, false) / at(1, false)
    );
}
