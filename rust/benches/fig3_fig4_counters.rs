//! Bench harness — Figures 3 + 4: stall cycles with outstanding loads and
//! per-level cache hit ratios for the aligned-read micro-benchmark.

mod common;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::figure3_4;
use multistride::report::figures::{render_hit_ratios, render_stalls};

fn main() {
    let points = common::stage("figure 3/4 counters", || figure3_4(coffee_lake(), common::scale()));
    print!("{}", render_stalls(&points));
    println!();
    print!("{}", render_hit_ratios(&points));

    // §4.3's qualitative checks.
    let on: Vec<_> = points.iter().filter(|p| p.prefetch).collect();
    let l1_pinned = on.iter().all(|p| (p.result.l1.hit_ratio() - 0.5).abs() < 0.05);
    println!("\nL1 hit ratio pinned at 0.5 across stride counts: {l1_pinned} (paper: yes)");
    let rising = on.first().map(|f| f.result.l2.hit_ratio()).unwrap_or(0.0)
        < on.last().map(|l| l.result.l2.hit_ratio()).unwrap_or(0.0);
    println!("L2 hit ratio rises with strides: {rising} (paper: yes)");
}
