//! Bench harness — Figure 7: best multi-strided kernels vs the reference
//! implementation models (CLang / Polly / no-unroll / best single-strided /
//! MKL / OpenBLAS / Halide×3 / OpenCV) on all three machine presets.

mod common;

use multistride::config::MachinePreset;
use multistride::coordinator::experiments::{figure7, figure7_kernels};
use multistride::report::figures::render_comparison;

fn main() {
    let scale = common::scale();
    let max_total = if std::env::var("MULTISTRIDE_BENCH_SMOKE").is_ok() { 8 } else { 20 };
    for preset in MachinePreset::all() {
        let machine = preset.config();
        for kernel in figure7_kernels() {
            let rows = common::stage(&format!("{} / {kernel}", machine.name), || {
                figure7(machine, &kernel, scale.kernel_bytes, max_total)
            });
            print!("{}", render_comparison(machine.name, &rows));
            println!();
        }
    }
}
