//! Bench harness — dynamic work distribution at both scales:
//!
//! 1. **Local imbalance** — a front-loaded skewed job mix through the
//!    static chunked baseline (`parallel_map_with_static`) vs the
//!    work-stealing pool (`parallel_map_with`) at 4 workers. Static
//!    chunking idles three workers behind the heavy chunk; stealing
//!    spreads it. The PR gate wants `local_dynamic_speedup_x100 >= 150`.
//! 2. **Fleet scaling** — one coordinator draining the same micro plan
//!    with 1, 2 and 4 connected workers (each single-threaded, so the
//!    curve measures the fleet, not the inner pool). Points/s per
//!    width, plus `fleet_scaling_2w_x100` / `fleet_scaling_4w_x100`.
//! 3. **Lease-reassignment overhead** — a 2-worker drain where one
//!    worker abandons its first batch mid-run vs a clean 2-worker
//!    drain: `lease_reassign_overhead_pct` is the wall-clock cost of
//!    losing a worker.
//!
//! Knobs (environment):
//! * `MULTISTRIDE_GRID_SPIN` — iterations per heavy local job
//!   (default 2,000,000; the light jobs run 1/16th of it).
//! * `MULTISTRIDE_GRID_POINTS` — fleet plan size (default 8).
//! * `MULTISTRIDE_BENCH_SMOKE` — shrink both for CI.
//! * `MULTISTRIDE_BENCH_JSON` — output path (default `BENCH_grid.json`).

mod common;

use std::time::Instant;

use common::{env_u64, stage, write_bench_json, JsonScenario};
use multistride::config::coffee_lake;
use multistride::coordinator::{parallel_map_with, parallel_map_with_static};
use multistride::exec::{ResultStore, SimPoint};
use multistride::grid::{run_worker, Coordinator, CoordinatorConfig, FleetReport, WorkerConfig};
use multistride::kernels::micro::MicroOp;

const POOL_WORKERS: usize = 4;
const LOCAL_REPS: usize = 3;

/// Deterministic spin work: `iters` FNV-style rounds the optimizer
/// cannot fold away.
fn spin(iters: u64) -> u64 {
    let mut acc = 0x9E37_79B9_7F4A_7C15u64;
    for i in 0..iters {
        acc = acc.wrapping_mul(0x1000_0000_1B3).wrapping_add(i);
        std::hint::black_box(acc);
    }
    acc
}

/// The skewed mix: the first quarter of the jobs carry 16× the work,
/// and static chunking hands that whole quarter to worker 0.
fn skewed_jobs(heavy: u64, n: usize) -> Vec<u64> {
    (0..n).map(|i| if i < n / 4 { heavy } else { heavy / 16 }).collect()
}

/// The fleet plan: micro points with distinct stride counts (and a
/// second working-set size once strides wrap), so every key is unique.
fn fleet_plan(n: usize) -> Vec<SimPoint> {
    (0..n)
        .map(|i| {
            let strides = 1 + (i % 8) as u32;
            let bytes = (1u64 << 21) << (i / 8);
            SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, strides, bytes, true, false)
        })
        .collect()
}

/// Drain `points` once with `k` healthy workers (plus, optionally, one
/// that abandons its first batch). Returns wall-clock seconds and the
/// coordinator's report.
fn fleet_drain(points: &[SimPoint], k: usize, with_crasher: bool) -> (f64, FleetReport) {
    let coord = Coordinator::bind(0).expect("bind port 0");
    let port = coord.port();
    let store = ResultStore::ephemeral();
    let cfg = CoordinatorConfig { lease_ms: 120_000, batch: 2 };
    let wcfg = WorkerConfig { batch: 2, local_workers: 1, max_batches: None, abandon_after: None };
    let t = Instant::now();
    let report = std::thread::scope(|scope| {
        let drain = scope.spawn(|| coord.run(&store, points, &cfg));
        if with_crasher {
            let crasher = scope.spawn(move || {
                let local = ResultStore::ephemeral();
                let cfg = WorkerConfig { abandon_after: Some(1), ..wcfg };
                run_worker("127.0.0.1", port, &local, points, &cfg)
            });
            let crashed = crasher.join().expect("crasher thread").expect("scripted crash");
            assert!(crashed.abandoned);
        }
        let workers: Vec<_> = (0..k)
            .map(|_| {
                scope.spawn(move || {
                    let local = ResultStore::ephemeral();
                    run_worker("127.0.0.1", port, &local, points, &wcfg)
                })
            })
            .collect();
        for w in workers {
            w.join().expect("worker thread").expect("worker run");
        }
        drain.join().expect("coordinator thread").expect("fleet drain")
    });
    let secs = t.elapsed().as_secs_f64();
    assert_eq!(report.results + report.already_present as u64, points.len() as u64);
    (secs, report)
}

fn main() {
    let smoke = std::env::var("MULTISTRIDE_BENCH_SMOKE").is_ok();
    let heavy = env_u64("MULTISTRIDE_GRID_SPIN", if smoke { 200_000 } else { 2_000_000 });
    let plan_n = env_u64("MULTISTRIDE_GRID_POINTS", if smoke { 4 } else { 8 }) as usize;
    let mut results = Vec::new();

    // ---------------------------------------------------------------
    // 1. Local imbalance: static chunking vs work stealing.
    // ---------------------------------------------------------------
    let jobs = skewed_jobs(heavy, 16 * POOL_WORKERS);
    let total_jobs = (jobs.len() * LOCAL_REPS) as u64;
    let (static_out, static_secs) = stage("local static, skewed mix", || {
        let t = Instant::now();
        let mut out = Vec::new();
        for _ in 0..LOCAL_REPS {
            out = parallel_map_with_static(jobs.clone(), POOL_WORKERS, || (), |_, &j| spin(j));
        }
        (out, t.elapsed().as_secs_f64())
    });
    let (dynamic_out, dynamic_secs) = stage("local dynamic, skewed mix", || {
        let t = Instant::now();
        let mut out = Vec::new();
        for _ in 0..LOCAL_REPS {
            out = parallel_map_with(jobs.clone(), POOL_WORKERS, || (), |_, &j| spin(j));
        }
        (out, t.elapsed().as_secs_f64())
    });
    assert_eq!(static_out, dynamic_out, "distribution must never change results");
    let speedup = static_secs / dynamic_secs;
    println!(
        "{:>42}: {:.2}x over static ({static_secs:.3} s -> {dynamic_secs:.3} s, \
         {} jobs x {LOCAL_REPS} reps, {POOL_WORKERS} workers)",
        "work stealing on the skewed mix",
        jobs.len(),
    );
    if speedup < 1.5 {
        println!("[bench] WARNING: dynamic speedup {speedup:.2}x below the 1.5x gate");
    }
    results.push(JsonScenario {
        label: "local static, skewed mix".into(),
        unit: "jobs",
        count: total_jobs,
        seconds: static_secs,
    });
    results.push(JsonScenario {
        label: "local dynamic, skewed mix".into(),
        unit: "jobs",
        count: total_jobs,
        seconds: dynamic_secs,
    });

    // ---------------------------------------------------------------
    // 2. Fleet scaling: the same plan at 1, 2 and 4 workers.
    // ---------------------------------------------------------------
    let points = fleet_plan(plan_n);
    // One unrecorded warmup drain so allocator and page-cache effects
    // land outside the measured runs.
    stage("fleet warmup", || fleet_drain(&points, 1, false));
    let mut per_width = Vec::new();
    for k in [1usize, 2, 4] {
        let (secs, report) = stage(&format!("fleet drain, {k} worker(s)"), || {
            fleet_drain(&points, k, false)
        });
        assert_eq!(report.workers, k as u64);
        println!(
            "{:>42}: {:>8.2} points/s ({} points, {secs:.3} s)",
            format!("fleet drain, {k} worker(s)"),
            points.len() as f64 / secs,
            points.len(),
        );
        results.push(JsonScenario {
            label: format!("fleet drain, {k} worker(s)"),
            unit: "points",
            count: points.len() as u64,
            seconds: secs,
        });
        per_width.push((k, secs));
    }
    let secs_at = |k: usize| per_width.iter().find(|(w, _)| *w == k).map(|(_, s)| *s).unwrap();
    let scale2 = secs_at(1) / secs_at(2);
    let scale4 = secs_at(1) / secs_at(4);
    println!(
        "{:>42}: 2w {scale2:.2}x, 4w {scale4:.2}x",
        "fleet scaling vs a single worker"
    );
    if scale2 < 1.7 {
        println!("[bench] WARNING: 2-worker fleet scaling {scale2:.2}x below the 1.7x gate");
    }

    // ---------------------------------------------------------------
    // 3. Lease-reassignment overhead: lose one worker mid-run.
    // ---------------------------------------------------------------
    let clean_secs = secs_at(2);
    let (chaos_secs, chaos_report) = stage("fleet drain, 2 workers, one abandons", || {
        fleet_drain(&points, 2, true)
    });
    assert!(
        chaos_report.reassigned >= 1,
        "the abandoned batch must be re-leased: {chaos_report:?}"
    );
    let overhead_pct = (chaos_secs / clean_secs - 1.0) * 100.0;
    println!(
        "{:>42}: {overhead_pct:+.1}% wall-clock vs clean ({} re-lease(s))",
        "lease reassignment after a worker loss",
        chaos_report.reassigned,
    );
    results.push(JsonScenario {
        label: "fleet drain, 2 workers, one abandons".into(),
        unit: "points",
        count: points.len() as u64,
        seconds: chaos_secs,
    });

    let extra: Vec<(&str, u64)> = vec![
        ("pool_workers", POOL_WORKERS as u64),
        ("heavy_spin_iters", heavy),
        ("plan_points", points.len() as u64),
        ("local_dynamic_speedup_x100", (speedup * 100.0).round() as u64),
        ("fleet_scaling_2w_x100", (scale2 * 100.0).round() as u64),
        ("fleet_scaling_4w_x100", (scale4 * 100.0).round() as u64),
        ("lease_reassign_overhead_pct", overhead_pct.max(0.0).round() as u64),
        ("chaos_reassignments", chaos_report.reassigned),
    ];
    let json_path =
        std::env::var("MULTISTRIDE_BENCH_JSON").unwrap_or_else(|_| "BENCH_grid.json".to_string());
    write_bench_json(&json_path, "grid", &extra, &results);
}
