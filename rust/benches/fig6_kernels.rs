//! Bench harness — Figure 6: the striding optimization space of every
//! isolated kernel, plus the green/red reference lines (best single-strided
//! and no-unroll) and the multi-striding speedup summary.

mod common;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{figure6_kernels, summarize_kernel};

fn main() {
    let scale = common::scale();
    let machine = coffee_lake();
    let max_total = if std::env::var("MULTISTRIDE_BENCH_SMOKE").is_ok() { 10 } else { 24 };

    println!(
        "{:>12} | {:>22} | {:>12} | {:>10} | {:>8}",
        "kernel", "best multi (s x p)", "GiB/s", "single", "speedup"
    );
    let mut gains = Vec::new();
    for kernel in figure6_kernels() {
        let s = common::stage(&format!("sweep {kernel}"), || {
            summarize_kernel(machine, &kernel, scale.kernel_bytes, max_total)
        });
        println!(
            "{:>12} | {:>14} {:>3} x {:<3} | {:>12.2} | {:>10.2} | {:>7.2}x",
            kernel,
            "",
            s.best_multi.config.stride_unroll,
            s.best_multi.config.portion_unroll,
            s.best_multi.throughput_gib,
            s.best_single.throughput_gib,
            s.multi_over_single()
        );
        gains.push(s.multi_over_single());
    }
    let geo = multistride::util::stats::geomean(&gains);
    println!("\ngeomean multi-over-single speedup: {geo:.3}x (paper band: 1.02x–1.58x)");
}
