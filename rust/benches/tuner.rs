//! Bench harness — tuner serving path: cold-search latency vs cache-hit
//! latency, in plans per second. This is the tune-once/serve-forever
//! claim made measurable: the cold column is what a first request for a
//! (kernel, machine, budget) pays, the hit column is what every
//! subsequent request pays.
//!
//! Besides the human-readable table, the harness emits `BENCH_tuner.json`
//! (same envelope as `BENCH_sim_hotpath.json`: per-scenario rates plus
//! machine and git-revision metadata), and asserts the plan cache
//! round-trips: every persisted plan re-parses to the exact bytes on
//! disk, and the warm pass serves byte-identical plans to the cold pass.
//!
//! Knobs (environment):
//! * `MULTISTRIDE_TUNER_BYTES` — per-kernel tuning budget in bytes
//!   (default 8 MiB; CI's advisory tuner-smoke job runs a reduced size).
//! * `MULTISTRIDE_BENCH_JSON` — output path for the JSON record
//!   (default `BENCH_tuner.json` in the working directory).

mod common;

use std::time::Instant;

use common::{env_u64, write_bench_json, JsonScenario};
use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{tune_kernel, tune_universe};
use multistride::runtime::universe_names;
use multistride::tune::{PlanCache, TunedPlan};

fn main() {
    let m = coffee_lake();
    let budget = env_u64("MULTISTRIDE_TUNER_BYTES", 8 * 1024 * 1024);
    let dir = std::env::temp_dir()
        .join(format!("multistride_tuner_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache = PlanCache::new(&dir);
    let n_kernels = universe_names(budget).len() as u64;
    let mut results = Vec::new();

    // Cold: every kernel in the registry searched in parallel.
    let t = Instant::now();
    let cold = tune_universe(m, budget, true, &cache, false);
    let cold_secs = t.elapsed().as_secs_f64();
    let failures = cold.iter().filter(|r| r.is_err()).count();
    assert_eq!(failures, 0, "cold tune must cover the whole registry");
    assert!(cold.iter().all(|r| !r.as_ref().unwrap().cache_hit));
    println!(
        "{:>42}: {:>8.2} plans/s ({n_kernels} plans, {cold_secs:.3} s)",
        "tune universe, cold search",
        n_kernels as f64 / cold_secs
    );
    results.push(JsonScenario {
        label: "tune universe, cold search".into(),
        unit: "plans",
        count: n_kernels,
        seconds: cold_secs,
    });

    // Round-trip wall: every persisted plan re-parses to its exact bytes.
    let files = cache.list();
    assert_eq!(files.len() as u64, n_kernels, "one plan per (kernel, machine)");
    for f in &files {
        let text = std::fs::read_to_string(f).unwrap();
        let plan = TunedPlan::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", f.display()));
        assert_eq!(plan.serialize(), text, "{}: disk round trip", f.display());
    }
    println!("{:>42}: {} plans verified", "plan-cache round trip", files.len());

    // Warm: the same universe served entirely from the plan cache.
    let t = Instant::now();
    let warm = tune_universe(m, budget, true, &cache, false);
    let warm_secs = t.elapsed().as_secs_f64();
    for (c, w) in cold.iter().zip(&warm) {
        let (c, w) = (c.as_ref().unwrap(), w.as_ref().unwrap());
        assert!(w.cache_hit, "{}: second pass must hit", w.plan.kernel);
        assert_eq!(
            c.plan.serialize(),
            w.plan.serialize(),
            "{}: hit serves the cold plan exactly",
            w.plan.kernel
        );
    }
    println!(
        "{:>42}: {:>8.2} plans/s ({n_kernels} plans, {warm_secs:.3} s)",
        "tune universe, cache hit",
        n_kernels as f64 / warm_secs
    );
    results.push(JsonScenario {
        label: "tune universe, cache hit".into(),
        unit: "plans",
        count: n_kernels,
        seconds: warm_secs,
    });

    // Single-plan hit latency, amortized over repeats (the serving-path
    // number: lookup + parse + staleness check, no simulation).
    let reps = 200u64;
    let t = Instant::now();
    for _ in 0..reps {
        let out = tune_kernel(m, "mxv", budget, true, &cache, false).unwrap();
        assert!(out.cache_hit);
    }
    let hit_secs = t.elapsed().as_secs_f64();
    println!(
        "{:>42}: {:>8.2} plans/s ({reps} hits, {:.1} us/hit)",
        "single-kernel cache hit (mxv)",
        reps as f64 / hit_secs,
        hit_secs / reps as f64 * 1e6
    );
    results.push(JsonScenario {
        label: "single-kernel cache hit (mxv)".into(),
        unit: "plans",
        count: reps,
        seconds: hit_secs,
    });

    println!(
        "\ncold search amortizes after {:.1} hits per kernel (cold {:.3} s vs hit {:.3} s per plan)",
        (cold_secs / n_kernels as f64) / (hit_secs / reps as f64),
        cold_secs / n_kernels as f64,
        hit_secs / reps as f64
    );

    let json_path =
        std::env::var("MULTISTRIDE_BENCH_JSON").unwrap_or_else(|_| "BENCH_tuner.json".into());
    write_bench_json(&json_path, "tuner", &[("budget_bytes", budget)], &results);
    std::fs::remove_dir_all(&dir).ok();
}
