//! Bench harness — result-store serving path: cold plan execution vs
//! warm-store serving (memory tier and disk tier), in points per second,
//! plus single-hit latency. This is the simulate-once/serve-forever
//! claim of the execution layer made measurable: the cold column is what
//! the first `repro all` pays per point, the warm columns are what every
//! overlapping sweep, re-run, or tune pays afterwards.
//!
//! Besides the human-readable table, the harness emits
//! `BENCH_result_store.json` (same envelope as the other bench records)
//! and asserts the transparency contract: warm passes perform **zero**
//! engine runs and serve results byte-identical to the cold pass.
//!
//! Knobs (environment):
//! * `MULTISTRIDE_STORE_BYTES` — array/budget size per point in bytes
//!   (default 8 MiB; CI-scale runs can shrink it).
//! * `MULTISTRIDE_BENCH_JSON` — output path for the JSON record
//!   (default `BENCH_result_store.json` in the working directory).

mod common;

use std::sync::Arc;
use std::time::Instant;

use common::{env_u64, write_bench_json, JsonScenario};
use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{EngineCache, MICRO_STRIDES};
use multistride::exec::format::serialize_result;
use multistride::exec::{Planner, ResultStore, SimPoint};
use multistride::kernels::library::kernel_by_name;
use multistride::kernels::micro::MicroOp;
use multistride::sim::RunResult;
use multistride::transform::{transform, variant_configs};

/// A `repro all`-shaped point set: the figure2 micro grid (sans the NT
/// interleave variant) plus every kernel family at portion 2.
fn build_points(bytes: u64) -> Vec<SimPoint> {
    let m = coffee_lake();
    let mut points = Vec::new();
    for prefetch in [true, false] {
        for op in MicroOp::all() {
            for &s in &MICRO_STRIDES {
                points.push(SimPoint::micro(m, op, s, bytes, prefetch, false));
            }
        }
    }
    for name in ["mxv", "bicg", "triad", "3mm"] {
        let pk = kernel_by_name(name, bytes).expect("registry kernel");
        for cfg in variant_configs(2) {
            if transform(&pk.spec, cfg).is_ok() {
                points.push(
                    SimPoint::kernel(m, name, bytes, cfg, true).expect("validated name"),
                );
            }
        }
    }
    points
}

fn run_plan(store: &ResultStore, points: &[SimPoint], label: &str) -> (Vec<Arc<RunResult>>, f64) {
    let t = Instant::now();
    let out = Planner::new(store).run(points).expect("plan executes");
    let secs = t.elapsed().as_secs_f64();
    println!(
        "{label:>42}: {:>10.1} points/s ({} points, {secs:.3} s)",
        points.len() as f64 / secs,
        points.len()
    );
    (out, secs)
}

fn main() {
    let bytes = env_u64("MULTISTRIDE_STORE_BYTES", 8 * 1024 * 1024);
    let dir = std::env::temp_dir()
        .join(format!("multistride_store_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let points = build_points(bytes);
    let n = points.len() as u64;
    let mut scenarios = Vec::new();

    // Cold: every distinct point simulates, write-through to disk.
    let cold_store = ResultStore::persistent(&dir);
    let (cold, cold_secs) = run_plan(&cold_store, &points, "cold plan (simulate + store)");
    let distinct = cold_store.stats().engine_runs;
    assert!(distinct > 0 && distinct <= n);
    scenarios.push(JsonScenario {
        label: "cold plan (simulate + store)".into(),
        unit: "points",
        count: n,
        seconds: cold_secs,
    });

    // Warm, memory tier: the same store instance re-serves the plan.
    let (warm_mem, mem_secs) = run_plan(&cold_store, &points, "warm plan (memory tier)");
    assert_eq!(
        cold_store.stats().engine_runs,
        distinct,
        "memory-tier pass must perform zero fresh engine runs"
    );
    scenarios.push(JsonScenario {
        label: "warm plan (memory tier)".into(),
        unit: "points",
        count: n,
        seconds: mem_secs,
    });

    // Warm, disk tier: a fresh store over the same directory (cold
    // memory) — what a second `repro all` invocation pays.
    let disk_store = ResultStore::persistent(&dir);
    let (warm_disk, disk_secs) = run_plan(&disk_store, &points, "warm plan (disk tier)");
    let s = disk_store.stats();
    assert_eq!(s.engine_runs, 0, "disk-tier pass must perform zero engine runs");
    assert_eq!(s.disk_hits, distinct);
    scenarios.push(JsonScenario {
        label: "warm plan (disk tier)".into(),
        unit: "points",
        count: n,
        seconds: disk_secs,
    });

    // Transparency: warm results are byte-identical to cold ones.
    for ((p, c), (m, d)) in points.iter().zip(&cold).zip(warm_mem.iter().zip(&warm_disk)) {
        let want = serialize_result(p.key(), c);
        assert_eq!(want, serialize_result(p.key(), m), "memory tier diverged: {}", p.label());
        assert_eq!(want, serialize_result(p.key(), d), "disk tier diverged: {}", p.label());
    }
    println!("{:>42}: warm results byte-identical to cold", "transparency wall");

    // Single-hit latency: repeated service of one point from the memory
    // tier (the cost a tuner rung pays to re-read a sweep's point).
    let hot = &points[0];
    let mut engines = EngineCache::new();
    let reps = 100_000u64;
    let t = Instant::now();
    for _ in 0..reps {
        let r = disk_store.get_or_run(&mut engines, hot).expect("hit");
        std::hint::black_box(&r);
    }
    let hit_secs = t.elapsed().as_secs_f64();
    println!(
        "{:>42}: {:>10.0} hits/s ({reps} hits, {hit_secs:.3} s, {:.2} µs/hit)",
        "single-hit latency (memory tier)",
        reps as f64 / hit_secs,
        hit_secs / reps as f64 * 1e6
    );
    scenarios.push(JsonScenario {
        label: "single-hit latency (memory tier)".into(),
        unit: "hits",
        count: reps,
        seconds: hit_secs,
    });

    let json_path = std::env::var("MULTISTRIDE_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_result_store.json".into());
    write_bench_json(
        &json_path,
        "result_store",
        &[("point_bytes", bytes), ("plan_points", n), ("distinct_points", distinct)],
        &scenarios,
    );
    std::fs::remove_dir_all(&dir).ok();
}
