//! Bench harness — result-store serving path: cold plan execution vs
//! warm-store serving (memory tier and disk tier), in points per second,
//! plus single-hit latency. This is the simulate-once/serve-forever
//! claim of the execution layer made measurable: the cold column is what
//! the first `repro all` pays per point, the warm columns are what every
//! overlapping sweep, re-run, or tune pays afterwards.
//!
//! Besides the human-readable table, the harness emits
//! `BENCH_result_store.json` (same envelope as the other bench records)
//! and asserts the transparency contract: warm passes perform **zero**
//! engine runs and serve results byte-identical to the cold pass.
//!
//! Knobs (environment):
//! * `MULTISTRIDE_STORE_BYTES` — array/budget size per point in bytes
//!   (default 8 MiB; CI-scale runs can shrink it).
//! * `MULTISTRIDE_STORE_SYNTH_POINTS` — synthetic-load size for the
//!   segment-vs-file-per-point section (default one million records).
//! * `MULTISTRIDE_STORE_MERGE_POINTS` — synthetic-load size for the
//!   grid merge-throughput section (default 200k records).
//! * `MULTISTRIDE_BENCH_JSON` — output path for the JSON record
//!   (default `BENCH_result_store.json` in the working directory).
//!
//! The synthetic section is the PR's acceptance bar made executable: the
//! warm-disk segment replay must sustain **at least 5×** the points/s of
//! the legacy file-per-point read path, measured in the same run, and
//! the harness asserts it hard.

mod common;

use std::sync::Arc;
use std::time::Instant;

use common::{env_u64, write_bench_json, JsonScenario};
use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{EngineCache, MICRO_STRIDES};
use multistride::exec::format::{decode_result_bin, serialize_result, RESULT_BIN_BYTES};
use multistride::exec::{grid, lifecycle, Planner, ResultStore, SimPoint};
use multistride::kernels::library::kernel_by_name;
use multistride::kernels::micro::MicroOp;
use multistride::sim::RunResult;
use multistride::transform::{transform, variant_configs};

/// A `repro all`-shaped point set: the figure2 micro grid (sans the NT
/// interleave variant) plus every kernel family at portion 2.
fn build_points(bytes: u64) -> Vec<SimPoint> {
    let m = coffee_lake();
    let mut points = Vec::new();
    for prefetch in [true, false] {
        for op in MicroOp::all() {
            for &s in &MICRO_STRIDES {
                points.push(SimPoint::micro(m, op, s, bytes, prefetch, false));
            }
        }
    }
    for name in ["mxv", "bicg", "triad", "3mm"] {
        let pk = kernel_by_name(name, bytes).expect("registry kernel");
        for cfg in variant_configs(2) {
            if transform(&pk.spec, cfg).is_ok() {
                points.push(
                    SimPoint::kernel(m, name, bytes, cfg, true).expect("validated name"),
                );
            }
        }
    }
    points
}

fn run_plan(store: &ResultStore, points: &[SimPoint], label: &str) -> (Vec<Arc<RunResult>>, f64) {
    let t = Instant::now();
    let out = Planner::new(store).run(points).expect("plan executes");
    let secs = t.elapsed().as_secs_f64();
    println!(
        "{label:>42}: {:>10.1} points/s ({} points, {secs:.3} s)",
        points.len() as f64 / secs,
        points.len()
    );
    (out, secs)
}

fn main() {
    let bytes = env_u64("MULTISTRIDE_STORE_BYTES", 8 * 1024 * 1024);
    let dir = std::env::temp_dir()
        .join(format!("multistride_store_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let points = build_points(bytes);
    let n = points.len() as u64;
    let mut scenarios = Vec::new();

    // Cold: every distinct point simulates, write-through to disk.
    let cold_store = ResultStore::persistent(&dir);
    let (cold, cold_secs) = run_plan(&cold_store, &points, "cold plan (simulate + store)");
    let distinct = cold_store.stats().engine_runs;
    assert!(distinct > 0 && distinct <= n);
    scenarios.push(JsonScenario {
        label: "cold plan (simulate + store)".into(),
        unit: "points",
        count: n,
        seconds: cold_secs,
    });

    // Warm, memory tier: the same store instance re-serves the plan.
    let (warm_mem, mem_secs) = run_plan(&cold_store, &points, "warm plan (memory tier)");
    assert_eq!(
        cold_store.stats().engine_runs,
        distinct,
        "memory-tier pass must perform zero fresh engine runs"
    );
    scenarios.push(JsonScenario {
        label: "warm plan (memory tier)".into(),
        unit: "points",
        count: n,
        seconds: mem_secs,
    });

    // Warm, disk tier: a fresh store over the same directory (cold
    // memory) — what a second `repro all` invocation pays.
    let disk_store = ResultStore::persistent(&dir);
    let (warm_disk, disk_secs) = run_plan(&disk_store, &points, "warm plan (disk tier)");
    let s = disk_store.stats();
    assert_eq!(s.engine_runs, 0, "disk-tier pass must perform zero engine runs");
    assert_eq!(s.disk_hits, distinct);
    scenarios.push(JsonScenario {
        label: "warm plan (disk tier)".into(),
        unit: "points",
        count: n,
        seconds: disk_secs,
    });

    // Transparency: warm results are byte-identical to cold ones.
    for ((p, c), (m, d)) in points.iter().zip(&cold).zip(warm_mem.iter().zip(&warm_disk)) {
        let want = serialize_result(p.key(), c);
        assert_eq!(want, serialize_result(p.key(), m), "memory tier diverged: {}", p.label());
        assert_eq!(want, serialize_result(p.key(), d), "disk tier diverged: {}", p.label());
    }
    println!("{:>42}: warm results byte-identical to cold", "transparency wall");

    // Single-hit latency: repeated service of one point from the memory
    // tier (the cost a tuner rung pays to re-read a sweep's point).
    let hot = &points[0];
    let mut engines = EngineCache::new();
    let reps = 100_000u64;
    let t = Instant::now();
    for _ in 0..reps {
        let r = disk_store.get_or_run(&mut engines, hot).expect("hit");
        std::hint::black_box(&r);
    }
    let hit_secs = t.elapsed().as_secs_f64();
    println!(
        "{:>42}: {:>10.0} hits/s ({reps} hits, {hit_secs:.3} s, {:.2} µs/hit)",
        "single-hit latency (memory tier)",
        reps as f64 / hit_secs,
        hit_secs / reps as f64 * 1e6
    );
    scenarios.push(JsonScenario {
        label: "single-hit latency (memory tier)".into(),
        unit: "hits",
        count: reps,
        seconds: hit_secs,
    });

    // ——— Million-point synthetic load: file-per-point vs segments, in
    // the same run. The legacy baseline is capped (its per-file tail IS
    // the measured cost; a million tiny files would make the harness,
    // not the store, the bottleneck) and replayed through the legacy
    // read path; the segment tier packs the full synthetic load and
    // replays it cold from disk.
    let synth_n = env_u64("MULTISTRIDE_STORE_SYNTH_POINTS", 1_000_000);
    let base_n = (synth_n / 20).clamp(1, 50_000);

    let base_dir =
        std::env::temp_dir().join(format!("multistride_store_bench_legacy_{}", std::process::id()));
    std::fs::remove_dir_all(&base_dir).ok();
    let writer = ResultStore::persistent(&base_dir);
    for i in 0..base_n {
        writer.write_legacy_shard(synth_key(i), &synth_result(i)).expect("legacy shard writes");
    }
    drop(writer);
    let legacy_store = ResultStore::persistent(&base_dir);
    let t = Instant::now();
    for i in 0..base_n {
        let r = legacy_store.lookup(synth_key(i)).expect("legacy shard serves");
        std::hint::black_box(&r);
    }
    let base_secs = t.elapsed().as_secs_f64();
    let base_rate = base_n as f64 / base_secs;
    let ls = legacy_store.stats();
    assert_eq!((ls.disk_hits, ls.legacy_hits), (base_n, base_n), "baseline must read shards");
    println!(
        "{:>42}: {base_rate:>10.1} points/s ({base_n} points, {base_secs:.3} s)",
        "synthetic: legacy file-per-point (warm)"
    );
    scenarios.push(JsonScenario {
        label: "synthetic: legacy file-per-point (warm)".into(),
        unit: "points",
        count: base_n,
        seconds: base_secs,
    });

    let seg_dir =
        std::env::temp_dir().join(format!("multistride_store_bench_seg_{}", std::process::id()));
    std::fs::remove_dir_all(&seg_dir).ok();
    let pack_store = ResultStore::persistent(&seg_dir);
    let t = Instant::now();
    for i in 0..synth_n {
        pack_store.insert(synth_key(i), Arc::new(synth_result(i)));
    }
    drop(pack_store); // seals the run: flushes the index
    let pack_secs = t.elapsed().as_secs_f64();
    println!(
        "{:>42}: {:>10.1} points/s ({synth_n} points, {pack_secs:.3} s)",
        "synthetic: segment pack (insert + index)",
        synth_n as f64 / pack_secs
    );
    scenarios.push(JsonScenario {
        label: "synthetic: segment pack (insert + index)".into(),
        unit: "points",
        count: synth_n,
        seconds: pack_secs,
    });

    let seg_store = ResultStore::persistent(&seg_dir);
    let t = Instant::now();
    for i in 0..synth_n {
        let r = seg_store.lookup(synth_key(i)).expect("segment record serves");
        std::hint::black_box(&r);
    }
    let warm_secs = t.elapsed().as_secs_f64();
    let warm_rate = synth_n as f64 / warm_secs;
    let ss = seg_store.stats();
    assert_eq!(
        (ss.disk_hits, ss.legacy_hits, ss.engine_runs),
        (synth_n, 0, 0),
        "segment replay must be pure disk hits"
    );
    // Spot-check the transparency contract at the edges and the middle.
    for i in [0, synth_n / 2, synth_n - 1] {
        let got = seg_store.lookup(synth_key(i)).expect("hit");
        assert_eq!(
            serialize_result(synth_key(i), &got),
            serialize_result(synth_key(i), &synth_result(i)),
            "synthetic record {i} diverged"
        );
    }
    println!(
        "{:>42}: {warm_rate:>10.1} points/s ({synth_n} points, {warm_secs:.3} s, {:.1}x baseline)",
        "synthetic: segment replay (warm disk)",
        warm_rate / base_rate
    );
    scenarios.push(JsonScenario {
        label: "synthetic: segment replay (warm disk)".into(),
        unit: "points",
        count: synth_n,
        seconds: warm_secs,
    });
    assert!(
        warm_rate >= 5.0 * base_rate,
        "segment warm-disk replay must be >= 5x the file-per-point baseline \
         (got {warm_rate:.0} vs {base_rate:.0} points/s)"
    );

    // ——— Grid merge throughput: the synthetic load split across two
    // disjoint shard stores by the grid partition function, then folded
    // back into one store by content key — what a two-host grid run
    // pays to reassemble a single results directory.
    let merge_n = env_u64("MULTISTRIDE_STORE_MERGE_POINTS", 200_000);
    let pid = std::process::id();
    let shard_dirs = [
        std::env::temp_dir().join(format!("multistride_store_bench_sh1_{pid}")),
        std::env::temp_dir().join(format!("multistride_store_bench_sh2_{pid}")),
    ];
    let merged_dir = std::env::temp_dir().join(format!("multistride_store_bench_merged_{pid}"));
    for d in &shard_dirs {
        std::fs::remove_dir_all(d).ok();
    }
    std::fs::remove_dir_all(&merged_dir).ok();
    {
        let shards =
            [ResultStore::persistent(&shard_dirs[0]), ResultStore::persistent(&shard_dirs[1])];
        for i in 0..merge_n {
            let key = synth_key(i);
            shards[grid::shard_of(key, 2) as usize - 1].insert(key, Arc::new(synth_result(i)));
        }
    } // drop seals both shard stores: indexes flushed
    let sources = shard_dirs.to_vec();
    let t = Instant::now();
    let report = grid::merge(&sources, &merged_dir).expect("merge runs");
    let merge_secs = t.elapsed().as_secs_f64();
    assert!(report.is_clean(), "disjoint shards cannot conflict");
    assert_eq!(report.merged, merge_n, "every shard record folds in");
    println!(
        "{:>42}: {:>10.1} points/s ({merge_n} points, {merge_secs:.3} s)",
        "grid merge (two disjoint shards)",
        merge_n as f64 / merge_secs
    );
    scenarios.push(JsonScenario {
        label: "grid merge (two disjoint shards)".into(),
        unit: "points",
        count: merge_n,
        seconds: merge_secs,
    });

    let t = Instant::now();
    let again = grid::merge(&sources, &merged_dir).expect("re-merge runs");
    let remerge_secs = t.elapsed().as_secs_f64();
    assert_eq!((again.merged, again.already_present), (0, merge_n), "re-merge is a pure no-op");
    println!(
        "{:>42}: {:>10.1} points/s ({merge_n} points, {remerge_secs:.3} s)",
        "grid re-merge (idempotent no-op)",
        merge_n as f64 / remerge_secs
    );
    scenarios.push(JsonScenario {
        label: "grid re-merge (idempotent no-op)".into(),
        unit: "points",
        count: merge_n,
        seconds: remerge_secs,
    });
    let merged_stats = lifecycle::dir_stats(&merged_dir);
    assert_eq!(merged_stats.live_records, merge_n, "merged store holds the full set");
    let merged_store = ResultStore::persistent(&merged_dir);
    for i in [0, merge_n / 2, merge_n - 1] {
        let got = merged_store.lookup(synth_key(i)).expect("merged record serves");
        assert_eq!(
            serialize_result(synth_key(i), &got),
            serialize_result(synth_key(i), &synth_result(i)),
            "merged record {i} diverged"
        );
    }
    drop(merged_store);

    let json_path = std::env::var("MULTISTRIDE_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_result_store.json".into());
    write_bench_json(
        &json_path,
        "result_store",
        &[
            ("point_bytes", bytes),
            ("plan_points", n),
            ("distinct_points", distinct),
            ("synthetic_points", synth_n),
            ("baseline_points", base_n),
            ("merge_points", merge_n),
        ],
        &scenarios,
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&base_dir).ok();
    std::fs::remove_dir_all(&seg_dir).ok();
    for d in &shard_dirs {
        std::fs::remove_dir_all(d).ok();
    }
    std::fs::remove_dir_all(&merged_dir).ok();
}

/// Synthetic content key i — a splitmix-style spread keeps the shard
/// fan-out and segment index realistic.
fn synth_key(i: u64) -> u64 {
    (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// A fully populated synthetic result: every field pseudo-random (so no
/// accidental zero-compression flatters either codec), frequency fixed
/// at a printable value for the text twin.
fn synth_result(i: u64) -> multistride::sim::RunResult {
    let mut state = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    let mut bytes = [0u8; RESULT_BIN_BYTES];
    for chunk in bytes.chunks_exact_mut(8) {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        chunk.copy_from_slice(&state.to_le_bytes());
    }
    let tail = RESULT_BIN_BYTES - 8;
    bytes[tail..].copy_from_slice(&3.2f64.to_bits().to_le_bytes());
    decode_result_bin(&bytes).expect("fixed-size buffer decodes")
}
