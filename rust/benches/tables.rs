//! Bench harness — Tables 1 and 2: the kernel overview (with stride-stream
//! profiles computed from the transform) and the machine presets, plus the
//! extended kernel universe in the same stride-profile format.

mod common;

use multistride::config::MachinePreset;
use multistride::kernels::library::{extended_kernels, paper_kernels};
use multistride::report::table::Table;
use multistride::transform::{stride_profile, transform, StridingConfig};

fn main() {
    let scale = common::scale();

    let mut t1 = Table::new(&["name", "AT", "L", "S", "L/S", "IN", "WB", "LE", "LI", "LB"])
        .with_title("Table 1 — stride columns computed at n=4");
    for pk in paper_kernels(scale.kernel_bytes) {
        let prof = transform(&pk.spec, StridingConfig::new(4, 2))
            .map(|tr| stride_profile(&tr))
            .expect("library kernels transform");
        let yn = |b: bool| if b { "Y" } else { "" }.to_string();
        t1.row(vec![
            pk.name.clone(),
            if pk.aligned { "A" } else { "U" }.into(),
            prof.loads.to_string(),
            prof.stores.to_string(),
            prof.loadstores.to_string(),
            yn(pk.has_init),
            yn(pk.has_writeback),
            if pk.loop_embedment > 0 { pk.loop_embedment.to_string() } else { String::new() },
            yn(pk.loop_interchange),
            yn(pk.loop_blocking),
        ]);
    }
    t1.print();
    println!();

    let mut tu = Table::new(&["name", "AT", "L", "S", "L/S", "loops", "description"])
        .with_title("Extended kernel universe — stride columns computed at n=4");
    for pk in extended_kernels(scale.kernel_bytes) {
        // Visible skip, not a panic: same no-silent-coverage policy as the
        // figure6 / variant_sweep paths.
        let prof = match transform(&pk.spec, StridingConfig::new(4, 2)) {
            Ok(tr) => stride_profile(&tr),
            Err(e) => {
                eprintln!("[tables] SKIPPED {}: {e}", pk.name);
                continue;
            }
        };
        tu.row(vec![
            pk.name.clone(),
            if pk.aligned { "A" } else { "U" }.into(),
            prof.loads.to_string(),
            prof.stores.to_string(),
            prof.loadstores.to_string(),
            pk.spec.loops.len().to_string(),
            pk.description.into(),
        ]);
    }
    tu.print();
    println!();

    let mut t2 = Table::new(&["machine", "freq", "L2", "L3", "paper BW", "model BW"])
        .with_title("Table 2 — machine presets vs modeled rooflines");
    for p in MachinePreset::all() {
        let m = p.config();
        t2.row(vec![
            m.name.into(),
            format!("{:.1} GHz", m.freq_ghz),
            format!("{} KiB/{}w", m.l2.size_bytes / 1024, m.l2.ways),
            format!("{:.1} MiB/{}w", m.l3.size_bytes as f64 / 1048576.0, m.l3.ways),
            format!("{:.2}", m.bandwidth_gib),
            format!("{:.2}", m.model_peak_gib()),
        ]);
    }
    t2.print();
}
