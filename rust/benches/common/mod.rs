//! Shared helpers for the bench harnesses.
//!
//! criterion is unavailable offline; each bench binary (`harness = false`)
//! is a self-timed harness that regenerates one paper table/figure and
//! prints wall-clock cost. `MULTISTRIDE_BENCH_SMOKE=1` switches to the
//! smoke scale for quick runs.
//!
//! Each bench compiles this module separately and uses a subset of it.
#![allow(dead_code)]

use multistride::config::ScaleConfig;
use std::time::Instant;

/// Scale selected by the environment.
pub fn scale() -> ScaleConfig {
    if std::env::var("MULTISTRIDE_BENCH_SMOKE").is_ok() {
        ScaleConfig::smoke()
    } else {
        ScaleConfig::default()
    }
}

/// Run a named stage, print its wall-clock time, return its value.
pub fn stage<T>(name: &str, f: impl FnOnce() -> T) -> T {
    eprintln!("[bench] {name} ...");
    let t = Instant::now();
    let v = f();
    eprintln!("[bench] {name}: {:.2} s", t.elapsed().as_secs_f64());
    v
}

/// u64 knob from the environment, with a default.
pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Current git revision: `git rev-parse`, else CI's `GITHUB_SHA`, else
/// "unknown". Best-effort — a bench record must never fail on it.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .or_else(|| std::env::var("GITHUB_SHA").ok())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Minimal JSON string escape (labels are plain ASCII, but stay correct).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One scenario row of a bench JSON record, rendered as
/// `{"label": …, "<unit>": count, "seconds": …, "<unit>_per_sec": rate}`
/// — `unit` is the bench's work unit ("accesses", "plans", …).
pub struct JsonScenario {
    pub label: String,
    pub unit: &'static str,
    pub count: u64,
    pub seconds: f64,
}

impl JsonScenario {
    pub fn rate(&self) -> f64 {
        self.count as f64 / self.seconds
    }
}

/// Write the shared bench-JSON envelope every harness emits:
/// `{bench, schema, unix_time, git_rev, machine, <extra numeric fields>,
/// scenarios: [...]}` — one format, so per-label rates stay diffable
/// across benches and commits (see ARCHITECTURE.md §Perf).
pub fn write_bench_json(
    path: &str,
    bench: &str,
    extra: &[(&str, u64)],
    scenarios: &[JsonScenario],
) {
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"bench\": \"{}\",\n  \"schema\": 1,\n", json_escape(bench)));
    s.push_str(&format!("  \"unix_time\": {unix_time},\n"));
    s.push_str(&format!("  \"git_rev\": \"{}\",\n", json_escape(&git_rev())));
    s.push_str(&format!(
        "  \"machine\": {{\"os\": \"{}\", \"arch\": \"{}\", \"cpus\": {cpus}}},\n",
        std::env::consts::OS,
        std::env::consts::ARCH
    ));
    for (k, v) in extra {
        s.push_str(&format!("  \"{}\": {v},\n", json_escape(k)));
    }
    s.push_str("  \"scenarios\": [\n");
    for (i, r) in scenarios.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"label\": \"{}\", \"{}\": {}, \"seconds\": {:.6}, \"{}_per_sec\": {:.3}}}{}\n",
            json_escape(&r.label),
            r.unit,
            r.count,
            r.seconds,
            r.unit,
            r.rate(),
            if i + 1 == scenarios.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\n[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}
