//! Shared helpers for the bench harnesses.
//!
//! criterion is unavailable offline; each bench binary (`harness = false`)
//! is a self-timed harness that regenerates one paper table/figure and
//! prints wall-clock cost. `MULTISTRIDE_BENCH_SMOKE=1` switches to the
//! smoke scale for quick runs.
//!
//! Each bench compiles this module separately and uses a subset of it.
#![allow(dead_code)]

use multistride::config::ScaleConfig;
use std::time::Instant;

/// Scale selected by the environment.
pub fn scale() -> ScaleConfig {
    if std::env::var("MULTISTRIDE_BENCH_SMOKE").is_ok() {
        ScaleConfig::smoke()
    } else {
        ScaleConfig::default()
    }
}

/// Run a named stage, print its wall-clock time, return its value.
pub fn stage<T>(name: &str, f: impl FnOnce() -> T) -> T {
    eprintln!("[bench] {name} ...");
    let t = Instant::now();
    let v = f();
    eprintln!("[bench] {name}: {:.2} s", t.elapsed().as_secs_f64());
    v
}
