//! Bench harness — ablations over the design choices DESIGN.md calls out:
//!
//! * streamer tracker-table size (what breaks first beyond 32 streams),
//! * per-stream outstanding-prefetch budget (the single-stride ceiling),
//! * lookahead distance ramp,
//! * next-page carry on/off (training cost per 4 KiB page),
//! * write-combining pool size (the NT-store cliff position).
//!
//! Each ablation varies ONE knob of the calibrated Coffee Lake preset and
//! reports the micro-benchmark read (or NT-store) curve.

mod common;

use multistride::config::coffee_lake;
use multistride::kernels::micro::{MicroBench, MicroOp};
use multistride::sim::{Engine, EngineConfig};

fn read_curve(cfg_fn: impl Fn(&mut EngineConfig), bytes: u64) -> Vec<f64> {
    [1u32, 2, 4, 8, 16, 32]
        .iter()
        .map(|&s| {
            let b = MicroBench::new(MicroOp::LoadAligned, s, bytes);
            let mut ec = EngineConfig::new(coffee_lake()).with_huge_pages(true);
            cfg_fn(&mut ec);
            Engine::new(ec).run(b.trace()).throughput_gib()
        })
        .collect()
}

fn print_curve(label: &str, curve: &[f64]) {
    print!("{label:>44}:");
    for v in curve {
        print!(" {v:>6.2}");
    }
    println!();
}

fn main() {
    let bytes = common::scale().micro_bytes;
    println!("aligned-read GiB/s at strides [1 2 4 8 16 32], {} MiB array\n", bytes >> 20);

    print_curve("calibrated baseline", &read_curve(|_| {}, bytes));

    for table in [8u32, 16, 32, 48, 64] {
        let c = read_curve(|ec| ec.prefetch.streamer.table_size = table, bytes);
        print_curve(&format!("streamer table_size={table}"), &c);
    }
    println!();
    for outs in [4u32, 8, 16, 24] {
        let c = read_curve(|ec| ec.prefetch.streamer.per_stream_outstanding = outs, bytes);
        print_curve(&format!("per_stream_outstanding={outs}"), &c);
    }
    println!();
    for dist in [8u32, 16, 24, 32] {
        let c = read_curve(|ec| ec.prefetch.streamer.max_distance = dist, bytes);
        print_curve(&format!("max_distance={dist}"), &c);
    }
    println!();
    for carry in [true, false] {
        let c = read_curve(|ec| ec.prefetch.streamer.next_page_carry = carry, bytes);
        print_curve(&format!("next_page_carry={carry}"), &c);
    }
    println!();
    // WC pool: where does the interleaved NT-store cliff sit?
    println!("interleaved NT-store GiB/s at strides [1 2 4 8 16 32]:");
    for entries in [6u32, 10, 14, 20] {
        let curve: Vec<f64> = [1u32, 2, 4, 8, 16, 32]
            .iter()
            .map(|&s| {
                let b = MicroBench::new(MicroOp::StoreNt, s, bytes).interleaved();
                let mut ec = EngineConfig::new(coffee_lake()).with_huge_pages(true);
                ec.machine.wc.entries = entries;
                Engine::new(ec).run(b.trace()).throughput_gib()
            })
            .collect();
        print_curve(&format!("wc entries={entries}"), &curve);
    }
    println!("\nreading: the cliff moves right as the WC pool grows — the §4.4 mechanism.");
}
