//! Bench harness — simulator hot path: simulated vector accesses per
//! second, per configuration class. This is the §Perf instrument: the
//! paper harnesses sweep hundreds of configurations, so the simulator's
//! access rate bounds total experiment wall-clock.
//!
//! Besides the human-readable table, the harness emits
//! `BENCH_sim_hotpath.json` — accesses/s per scenario plus machine and
//! git-revision metadata — so the perf trajectory is machine-diffable
//! across commits (see ARCHITECTURE.md §Perf for how to read it).
//!
//! Knobs (environment):
//! * `MULTISTRIDE_HOTPATH_BYTES` — per-scenario array footprint in bytes
//!   (default 32 MiB; CI's advisory perf-smoke job runs a reduced size).
//! * `MULTISTRIDE_BENCH_JSON` — output path for the JSON record
//!   (default `BENCH_sim_hotpath.json` in the working directory).
//!
//! The final section measures the engine-reuse path the coordinator
//! sweeps use ([`Engine::prepare`] via `EngineCache`) against fresh
//! construction per configuration point.

mod common;

use std::time::Instant;

use common::{env_u64, write_bench_json, JsonScenario};
use multistride::config::coffee_lake;
use multistride::coordinator::experiments::EngineCache;
use multistride::kernels::library::{all_kernels, kernel_by_name};
use multistride::kernels::micro::{MicroBench, MicroOp};
use multistride::sim::{Engine, EngineConfig};
use multistride::trace::KernelTrace;
use multistride::transform::{transform, StridingConfig};

fn rate(
    results: &mut Vec<JsonScenario>,
    label: impl Into<String>,
    accesses: u64,
    f: impl FnOnce(),
) {
    let label = label.into();
    let t = Instant::now();
    f();
    let s = t.elapsed().as_secs_f64();
    println!(
        "{label:>42}: {:>8.2} M accesses/s ({accesses} accesses, {s:.3} s)",
        accesses as f64 / s / 1e6
    );
    results.push(JsonScenario { label, unit: "accesses", count: accesses, seconds: s });
}

fn main() {
    let m = coffee_lake();
    let bytes = env_u64("MULTISTRIDE_HOTPATH_BYTES", 32 * 1024 * 1024);
    let mut results = Vec::new();

    for (label, strides, pf) in [
        ("micro read, 1 stride, pf on", 1u32, true),
        ("micro read, 16 strides, pf on", 16, true),
        ("micro read, 16 strides, pf off", 16, false),
    ] {
        let b = MicroBench::new(MicroOp::LoadAligned, strides, bytes);
        let n = b.trace_len();
        rate(&mut results, label, n, || {
            let mut e = Engine::new(EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true));
            let _ = e.run(b.trace());
        });
    }

    for (label, op) in [
        ("micro NT store, 16 strides", MicroOp::StoreNt),
        ("micro copy, 8 strides", MicroOp::CopyAligned),
    ] {
        let strides = if op == MicroOp::StoreNt { 16 } else { 8 };
        let b = MicroBench::new(op, strides, bytes);
        let n = b.trace_len();
        rate(&mut results, label, n, || {
            let mut e = Engine::new(EngineConfig::new(m).with_huge_pages(true));
            let _ = e.run(b.trace());
        });
    }

    // Kernel trace generation + simulation.
    let pk = kernel_by_name("mxv", bytes).unwrap();
    for (label, cfg) in [
        ("mxv trace gen only, s=4 p=2", StridingConfig::new(4, 2)),
        ("mxv simulate, s=1 p=8", StridingConfig::new(1, 8)),
        ("mxv simulate, s=8 p=1", StridingConfig::new(8, 1)),
    ] {
        let t = transform(&pk.spec, cfg).unwrap();
        let kt = KernelTrace::new(t);
        let n = kt.len_estimate();
        if label.contains("gen only") {
            rate(&mut results, label, n, || {
                let mut sink = 0u64;
                for a in kt.iter() {
                    sink ^= a.addr;
                }
                std::hint::black_box(sink);
            });
        } else {
            rate(&mut results, label, n, || {
                let mut e = Engine::new(EngineConfig::new(m));
                let _ = e.run(kt.iter());
            });
        }
    }

    // Kernel-universe trajectory: every registered kernel (paper +
    // extended) simulated at its single-stride baseline and the S=8
    // multi-strided variant, so new kernels land in the perf JSON
    // automatically (one `kernel <name> s=N` scenario each).
    let kernel_budget = (bytes / 8).max(2 * 1024 * 1024);
    for pk in all_kernels(kernel_budget) {
        for s in [1u32, 8] {
            let t = match transform(&pk.spec, StridingConfig::new(s, 1)) {
                Ok(t) => t,
                Err(e) => {
                    // Visible skip: a missing scenario in the JSON must
                    // never read as silent coverage.
                    println!("{:>42}: SKIPPED ({e})", format!("kernel {} s={s}", pk.name));
                    continue;
                }
            };
            let kt = KernelTrace::new(t);
            let n = kt.len_estimate();
            rate(&mut results, format!("kernel {} s={s}", pk.name), n, || {
                let mut e = Engine::new(EngineConfig::new(m));
                let _ = e.run(kt.iter());
            });
        }
    }

    // Sweep-style engine reuse: the same 8-point prefetch on/off sweep run
    // with a fresh engine per point vs one warm engine prepared per point
    // (what coordinator::EngineCache gives each worker).
    let sweep_bytes = (bytes / 4).max(1024 * 1024);
    let b = MicroBench::new(MicroOp::LoadAligned, 8, sweep_bytes);
    let points: Vec<bool> = [true, false].repeat(4);
    let n = b.trace_len() * points.len() as u64;
    rate(&mut results, "sweep x8, fresh engine per point", n, || {
        for &pf in &points {
            let mut e = Engine::new(EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true));
            let _ = e.run(b.trace());
        }
    });
    let mut cache = EngineCache::new();
    rate(&mut results, "sweep x8, reused engine (prepare)", n, || {
        for &pf in &points {
            let e = cache.engine_for(EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true));
            let _ = e.run(b.trace());
        }
    });

    let json_path =
        std::env::var("MULTISTRIDE_BENCH_JSON").unwrap_or_else(|_| "BENCH_sim_hotpath.json".into());
    write_bench_json(
        &json_path,
        "sim_hotpath",
        &[("bytes", bytes), ("sweep_bytes", sweep_bytes)],
        &results,
    );
}
