//! Bench harness — simulator hot path: simulated vector accesses per
//! second, per configuration class. This is the §Perf instrument: the
//! paper harnesses sweep hundreds of configurations, so the simulator's
//! access rate bounds total experiment wall-clock.
//!
//! Besides the human-readable table, the harness emits
//! `BENCH_sim_hotpath.json` — accesses/s per scenario plus machine and
//! git-revision metadata — so the perf trajectory is machine-diffable
//! across commits (see ARCHITECTURE.md §Perf for how to read it).
//!
//! Knobs (environment):
//! * `MULTISTRIDE_HOTPATH_BYTES` — per-scenario array footprint in bytes
//!   (default 32 MiB; CI's advisory perf-smoke job runs a reduced size).
//! * `MULTISTRIDE_BENCH_JSON` — output path for the JSON record
//!   (default `BENCH_sim_hotpath.json` in the working directory).
//!
//! The final section measures the engine-reuse path the coordinator
//! sweeps use ([`Engine::prepare`] via `EngineCache`) against fresh
//! construction per configuration point.

use std::time::Instant;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::EngineCache;
use multistride::kernels::library::{all_kernels, kernel_by_name};
use multistride::kernels::micro::{MicroBench, MicroOp};
use multistride::sim::{Engine, EngineConfig};
use multistride::trace::KernelTrace;
use multistride::transform::{transform, StridingConfig};

/// One measured scenario, kept for the JSON record.
struct Scenario {
    label: String,
    accesses: u64,
    seconds: f64,
}

impl Scenario {
    fn rate(&self) -> f64 {
        self.accesses as f64 / self.seconds
    }
}

fn rate(results: &mut Vec<Scenario>, label: impl Into<String>, accesses: u64, f: impl FnOnce()) {
    let label = label.into();
    let t = Instant::now();
    f();
    let s = t.elapsed().as_secs_f64();
    println!(
        "{label:>42}: {:>8.2} M accesses/s ({accesses} accesses, {s:.3} s)",
        accesses as f64 / s / 1e6
    );
    results.push(Scenario { label, accesses, seconds: s });
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Current git revision: `git rev-parse`, else CI's `GITHUB_SHA`, else
/// "unknown". Best-effort — the record must never fail on it.
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .or_else(|| std::env::var("GITHUB_SHA").ok())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Minimal JSON string escape (labels are plain ASCII, but stay correct).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn write_json(path: &str, bytes: u64, sweep_bytes: u64, results: &[Scenario]) {
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"sim_hotpath\",\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"unix_time\": {unix_time},\n"));
    s.push_str(&format!("  \"git_rev\": \"{}\",\n", json_escape(&git_rev())));
    s.push_str(&format!(
        "  \"machine\": {{\"os\": \"{}\", \"arch\": \"{}\", \"cpus\": {cpus}}},\n",
        std::env::consts::OS,
        std::env::consts::ARCH
    ));
    s.push_str(&format!("  \"bytes\": {bytes},\n  \"sweep_bytes\": {sweep_bytes},\n"));
    s.push_str("  \"scenarios\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"label\": \"{}\", \"accesses\": {}, \"seconds\": {:.6}, \"accesses_per_sec\": {:.1}}}{}\n",
            json_escape(&r.label),
            r.accesses,
            r.seconds,
            r.rate(),
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(path, &s) {
        Ok(()) => println!("\n[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

fn main() {
    let m = coffee_lake();
    let bytes = env_u64("MULTISTRIDE_HOTPATH_BYTES", 32 * 1024 * 1024);
    let mut results = Vec::new();

    for (label, strides, pf) in [
        ("micro read, 1 stride, pf on", 1u32, true),
        ("micro read, 16 strides, pf on", 16, true),
        ("micro read, 16 strides, pf off", 16, false),
    ] {
        let b = MicroBench::new(MicroOp::LoadAligned, strides, bytes);
        let n = b.trace_len();
        rate(&mut results, label, n, || {
            let mut e = Engine::new(EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true));
            let _ = e.run(b.trace());
        });
    }

    for (label, op) in [
        ("micro NT store, 16 strides", MicroOp::StoreNt),
        ("micro copy, 8 strides", MicroOp::CopyAligned),
    ] {
        let strides = if op == MicroOp::StoreNt { 16 } else { 8 };
        let b = MicroBench::new(op, strides, bytes);
        let n = b.trace_len();
        rate(&mut results, label, n, || {
            let mut e = Engine::new(EngineConfig::new(m).with_huge_pages(true));
            let _ = e.run(b.trace());
        });
    }

    // Kernel trace generation + simulation.
    let pk = kernel_by_name("mxv", bytes).unwrap();
    for (label, cfg) in [
        ("mxv trace gen only, s=4 p=2", StridingConfig::new(4, 2)),
        ("mxv simulate, s=1 p=8", StridingConfig::new(1, 8)),
        ("mxv simulate, s=8 p=1", StridingConfig::new(8, 1)),
    ] {
        let t = transform(&pk.spec, cfg).unwrap();
        let kt = KernelTrace::new(t);
        let n = kt.len_estimate();
        if label.contains("gen only") {
            rate(&mut results, label, n, || {
                let mut sink = 0u64;
                for a in kt.iter() {
                    sink ^= a.addr;
                }
                std::hint::black_box(sink);
            });
        } else {
            rate(&mut results, label, n, || {
                let mut e = Engine::new(EngineConfig::new(m));
                let _ = e.run(kt.iter());
            });
        }
    }

    // Kernel-universe trajectory: every registered kernel (paper +
    // extended) simulated at its single-stride baseline and the S=8
    // multi-strided variant, so new kernels land in the perf JSON
    // automatically (one `kernel <name> s=N` scenario each).
    let kernel_budget = (bytes / 8).max(2 * 1024 * 1024);
    for pk in all_kernels(kernel_budget) {
        for s in [1u32, 8] {
            let t = match transform(&pk.spec, StridingConfig::new(s, 1)) {
                Ok(t) => t,
                Err(e) => {
                    // Visible skip: a missing scenario in the JSON must
                    // never read as silent coverage.
                    println!("{:>42}: SKIPPED ({e})", format!("kernel {} s={s}", pk.name));
                    continue;
                }
            };
            let kt = KernelTrace::new(t);
            let n = kt.len_estimate();
            rate(&mut results, format!("kernel {} s={s}", pk.name), n, || {
                let mut e = Engine::new(EngineConfig::new(m));
                let _ = e.run(kt.iter());
            });
        }
    }

    // Sweep-style engine reuse: the same 8-point prefetch on/off sweep run
    // with a fresh engine per point vs one warm engine prepared per point
    // (what coordinator::EngineCache gives each worker).
    let sweep_bytes = (bytes / 4).max(1024 * 1024);
    let b = MicroBench::new(MicroOp::LoadAligned, 8, sweep_bytes);
    let points: Vec<bool> = [true, false].repeat(4);
    let n = b.trace_len() * points.len() as u64;
    rate(&mut results, "sweep x8, fresh engine per point", n, || {
        for &pf in &points {
            let mut e = Engine::new(EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true));
            let _ = e.run(b.trace());
        }
    });
    let mut cache = EngineCache::new();
    rate(&mut results, "sweep x8, reused engine (prepare)", n, || {
        for &pf in &points {
            let e = cache.engine_for(EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true));
            let _ = e.run(b.trace());
        }
    });

    let json_path =
        std::env::var("MULTISTRIDE_BENCH_JSON").unwrap_or_else(|_| "BENCH_sim_hotpath.json".into());
    write_json(&json_path, bytes, sweep_bytes, &results);
}
