//! Bench harness — Figure 5: the power-of-two cache-collision experiment.
//! Same grid as Figure 2 but over an exactly-power-of-two array, so equally
//! spaced strides alias to the same cache sets (§4.5).

mod common;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::figure2;
use multistride::kernels::micro::MicroOp;
use multistride::report::figures::render_micro_grid;

fn main() {
    let scale = common::scale();
    let pow2 = common::stage("figure 5 grid (pow2 array)", || figure2(coffee_lake(), scale, true));
    print!("{}", render_micro_grid(&pow2, "Figure 5 — power-of-two array"));

    let nonpow2 = common::stage("figure 2 reference points", || {
        use multistride::coordinator::experiments::run_micro;
        [8u32, 16, 32]
            .iter()
            .map(|&s| {
                run_micro(coffee_lake(), MicroOp::LoadAligned, s, scale.micro_bytes, true, false)
            })
            .collect::<Vec<_>>()
    });
    println!("\naligned reads, pow2 vs non-pow2 array (pf on):");
    for p in &nonpow2 {
        let bad = pow2
            .iter()
            .find(|q| {
                q.op == MicroOp::LoadAligned
                    && q.strides == p.strides
                    && q.prefetch
                    && !q.interleaved
            })
            .unwrap();
        println!(
            "  {:>2} strides: {:>6.2} GiB/s -> {:>6.2} GiB/s ({:.0}% of non-pow2; paper: collapse)",
            p.strides,
            p.throughput_gib,
            bad.throughput_gib,
            100.0 * bad.throughput_gib / p.throughput_gib
        );
    }
}
